//! Offline tuner (Fig. 6 ④⑤): consumes profile data from engines,
//! produces cached placement hints for subsequent invocations.
//!
//! Runs on its own thread so hint generation never blocks the request
//! path — the paper's "all metrics are sent to an offline tuner". The
//! hint cache is the "placement hint consists only of metadata that can
//! be cached on each server".
//!
//! With `[provision] enabled = true` the tuner additionally owns the
//! per-function DRAM provisioning loop (`placement::provision`): for
//! every profiled function it builds (or fetches from the process-wide
//! [`TraceStore`] memo) a latency-vs-DRAM demand curve by replaying the
//! function's canonical Trace-IR at the configured ladder, and on an
//! epoch cadence re-runs the [`BudgetAllocator`] across every resident
//! function — the per-function budgets replace the global
//! `porter.dram_budget_frac` in `PlacementHint::generate`. All of it
//! happens on the tuner thread, off the serving request path; callers
//! that deliberately `drain()` after a profiled run (the fleet
//! simulation's `Node::measure`, tests) do wait for the ladder replays
//! of a *first-seen* function, a one-off host-time cost per
//! `(workload, fingerprint)` amortized fleet-wide by the curve memo.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;

use crate::config::{Config, MachineConfig, PorterConfig, ProvisionConfig};
use crate::monitor::damon::Damon;
use crate::placement::hints::PlacementHint;
use crate::placement::provision::{self, BudgetAllocator, DemandCurve, FunctionDemand};
use crate::shim::object::MemoryObject;
use crate::sim::machine::RunReport;
use crate::trace::{TraceKey, TraceStore};

/// Shared hint cache (per-deployment; the paper caches per server, but
/// hints are tiny metadata — one map serves the simulation).
#[derive(Default)]
pub struct HintCache {
    map: RwLock<HashMap<String, PlacementHint>>,
    /// Best observed wall time per function (SLO reference).
    best_wall: RwLock<HashMap<String, f64>>,
}

impl HintCache {
    pub fn get(&self, function: &str) -> Option<PlacementHint> {
        self.map.read().unwrap().get(function).cloned()
    }

    pub fn put(&self, hint: PlacementHint) {
        self.map.write().unwrap().insert(hint.function.clone(), hint);
    }

    pub fn invalidate(&self, function: &str) {
        self.map.write().unwrap().remove(function);
        self.best_wall.write().unwrap().remove(function);
    }

    pub fn record_wall(&self, function: &str, wall_ns: f64) {
        let mut best = self.best_wall.write().unwrap();
        let e = best.entry(function.to_string()).or_insert(wall_ns);
        if wall_ns < *e {
            *e = wall_ns;
        }
    }

    /// SLO reference latency for a function, if any run has completed.
    pub fn best_wall(&self, function: &str) -> Option<f64> {
        self.best_wall.read().unwrap().get(function).copied()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Profile payload an engine ships after a monitored run.
pub struct ProfileData {
    pub function: String,
    pub damon: Box<Damon>,
    pub objects: Vec<MemoryObject>,
    pub report: RunReport,
    /// Trace-store key of the run's canonical stream (the provisioning
    /// loop's what-if source); `None` when the trace path is off.
    pub trace_key: Option<TraceKey>,
}

enum Msg {
    Profile(ProfileData),
    Stop,
}

/// Counter of in-flight profiles plus the condvar `drain` blocks on —
/// replaces the old `AtomicUsize` + `yield_now` busy-wait, which
/// livelocked forever if the worker thread had exited or a `submit`
/// incremented the counter and then failed to enqueue.
#[derive(Default)]
struct PendingGate {
    count: Mutex<usize>,
    cv: Condvar,
}

impl PendingGate {
    fn inc(&self) {
        *self.count.lock().unwrap() += 1;
    }

    fn dec(&self) {
        let mut c = self.count.lock().unwrap();
        *c = c.saturating_sub(1);
        if *c == 0 {
            self.cv.notify_all();
        }
    }

    fn wait_zero(&self) {
        let mut c = self.count.lock().unwrap();
        while *c > 0 {
            c = self.cv.wait(c).unwrap();
        }
    }
}

/// Provisioning-loop counters the fleet report rolls up.
#[derive(Debug, Default)]
pub struct ProvisionMetrics {
    /// Functions with a demand curve (latest snapshot).
    pub curves: AtomicU64,
    /// Allocator runs performed.
    pub reallocs: AtomicU64,
    /// Latest allocation's DRAM saved vs uniform provisioning (bytes).
    pub dram_saved_bytes: AtomicU64,
    /// SLO floors active in the latest allocation.
    pub floors: AtomicU64,
}

impl ProvisionMetrics {
    /// `(curves, reallocs, dram_saved_bytes)` snapshot.
    pub fn counts(&self) -> (u64, u64, u64) {
        (
            self.curves.load(Ordering::Relaxed),
            self.reallocs.load(Ordering::Relaxed),
            self.dram_saved_bytes.load(Ordering::Relaxed),
        )
    }
}

/// The tuner thread + its cache.
pub struct OfflineTuner {
    tx: Mutex<Sender<Msg>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    hints: Arc<HintCache>,
    pending: Arc<PendingGate>,
    pub processed: Arc<AtomicUsize>,
    provision: Arc<ProvisionMetrics>,
}

/// Worker-side state of the provisioning loop: the latest profile per
/// function (so hints can be regenerated when budgets move), the
/// per-function curves, and the budget fractions currently in force.
#[derive(Default)]
struct ProvisionState {
    profiles: HashMap<String, (Box<Damon>, Vec<MemoryObject>)>,
    curves: HashMap<String, Arc<DemandCurve>>,
    fracs: HashMap<String, f64>,
    since_realloc: u64,
}

impl ProvisionState {
    /// Re-run the allocator across every function with a curve; returns
    /// the functions (≠ `incoming`) whose budget fraction changed and
    /// therefore need their hint regenerated.
    fn reallocate(
        &mut self,
        incoming: &str,
        hints: &HintCache,
        machine: &MachineConfig,
        porter: &PorterConfig,
        cfg: &ProvisionConfig,
        metrics: &ProvisionMetrics,
    ) -> Vec<String> {
        self.since_realloc = 0;
        // detlint: allow(D1, reason = "keys are sorted before any consumer sees them")
        let mut names: Vec<String> = self.curves.keys().cloned().collect();
        names.sort();
        let demands: Vec<FunctionDemand> = names
            .iter()
            .map(|n| {
                let curve = self.curves[n].clone();
                let floor_bytes = if cfg.slo_floors {
                    hints
                        .best_wall(n)
                        .and_then(|best| curve.bytes_for_target(best * porter.slo_factor))
                } else {
                    None
                };
                FunctionDemand { curve, floor_bytes, weight: 1.0 }
            })
            .collect();
        let alloc = BudgetAllocator::from_config(cfg).allocate(machine.dram_bytes, &demands);
        metrics.reallocs.fetch_add(1, Ordering::Relaxed);
        metrics.curves.store(self.curves.len() as u64, Ordering::Relaxed);
        metrics.dram_saved_bytes.store(alloc.dram_saved_bytes(), Ordering::Relaxed);
        metrics.floors.store(
            demands.iter().filter(|d| d.floor_bytes.is_some()).count() as u64,
            Ordering::Relaxed,
        );
        let mut changed = Vec::new();
        // budgets come back in the demands' input order; key them by
        // the tuner's function names (a curve carries the *workload*
        // name, which needn't match the deployed function name)
        for (name, b) in names.iter().zip(&alloc.budgets) {
            let prev = self.fracs.insert(name.clone(), b.frac);
            let moved = prev.is_none_or(|p| (p - b.frac).abs() > 1e-9);
            if moved && name != incoming {
                changed.push(name.clone());
            }
        }
        changed
    }
}

impl OfflineTuner {
    pub fn new(cfg: &Config) -> OfflineTuner {
        let (tx, rx) = channel::<Msg>();
        let hints = Arc::new(HintCache::default());
        let pending = Arc::new(PendingGate::default());
        let processed = Arc::new(AtomicUsize::new(0));
        let provision_metrics = Arc::new(ProvisionMetrics::default());
        let worker = {
            let hints = Arc::clone(&hints);
            let pending = Arc::clone(&pending);
            let processed = Arc::clone(&processed);
            let metrics = Arc::clone(&provision_metrics);
            let machine = cfg.machine.clone();
            let porter = cfg.porter.clone();
            let prov_cfg = cfg.provision.clone();
            std::thread::Builder::new()
                .name("porter-tuner".into())
                .spawn(move || {
                    let mut state = ProvisionState::default();
                    while let Ok(Msg::Profile(p)) = rx.recv() {
                        let function = p.function.clone();
                        if !prov_cfg.enabled {
                            // legacy path: one hint from the global
                            // budget fraction, profile dropped after —
                            // nothing is retained per function
                            hints.put(PlacementHint::generate(
                                &function,
                                &p.damon,
                                &p.objects,
                                porter.dram_budget_frac,
                                porter.hot_threshold,
                            ));
                            pending.dec();
                            processed.fetch_add(1, Ordering::SeqCst);
                            continue;
                        }
                        let mut new_curve = false;
                        if let Some(key) = &p.trace_key {
                            if !state.curves.contains_key(&function) {
                                if let Some(c) = provision::curve_for_key(
                                    TraceStore::global(),
                                    key,
                                    &machine,
                                    &prov_cfg.ladder,
                                ) {
                                    state.curves.insert(function.clone(), c);
                                    new_curve = true;
                                }
                            }
                        }
                        // the latest profile is retained so hints can be
                        // regenerated whenever a realloc moves budgets
                        state.profiles.insert(function.clone(), (p.damon, p.objects));
                        state.since_realloc += 1;
                        if !state.curves.is_empty()
                            && (new_curve || state.since_realloc >= prov_cfg.epoch_profiles)
                        {
                            let changed = state.reallocate(
                                &function, &hints, &machine, &porter, &prov_cfg, &metrics,
                            );
                            // budgets moved: refresh the other
                            // functions' hints from their stored
                            // profiles (the incoming one regenerates
                            // below either way)
                            for name in changed {
                                if let Some((damon, objects)) = state.profiles.get(&name) {
                                    let frac = state.fracs[&name];
                                    hints.put(PlacementHint::generate(
                                        &name,
                                        damon,
                                        objects,
                                        frac,
                                        porter.hot_threshold,
                                    ));
                                }
                            }
                        }
                        let frac = state
                            .fracs
                            .get(&function)
                            .copied()
                            .unwrap_or(porter.dram_budget_frac);
                        let (damon, objects) =
                            state.profiles.get(&function).expect("profile just stored");
                        let hint = PlacementHint::generate(
                            &function,
                            damon,
                            objects,
                            frac,
                            porter.hot_threshold,
                        );
                        hints.put(hint);
                        pending.dec();
                        processed.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .expect("spawn tuner")
        };
        OfflineTuner {
            tx: Mutex::new(tx),
            worker: Mutex::new(Some(worker)),
            hints,
            pending,
            processed,
            provision: provision_metrics,
        }
    }

    pub fn hints(&self) -> &HintCache {
        &self.hints
    }

    /// Provisioning-loop counters (all zero when `[provision]` is off).
    pub fn provision_metrics(&self) -> &ProvisionMetrics {
        &self.provision
    }

    /// Ship a profile for asynchronous hint generation (Fig. 6 ④).
    /// If the worker has already exited, the profile is dropped and the
    /// pending counter rolled back so a later [`drain`] cannot hang on
    /// work nobody will ever do.
    ///
    /// [`drain`]: OfflineTuner::drain
    pub fn submit(&self, data: ProfileData) {
        self.pending.inc();
        if self.tx.lock().unwrap().send(Msg::Profile(data)).is_err() {
            self.pending.dec();
        }
    }

    /// Wait until all submitted profiles are processed (tests/benches).
    /// Blocks on a condvar rather than spinning; returns immediately
    /// when nothing is pending.
    pub fn drain(&self) {
        self.pending.wait_zero();
    }

    /// Stop the worker thread (idempotent; also runs on drop).
    /// In-flight profiles are processed first — the stop message queues
    /// behind them. The sender lock is held across the stop *and* the
    /// join: a concurrently racing `submit` would otherwise slip its
    /// profile behind the stop message, where the exiting worker drops
    /// it without decrementing `pending` and a later `drain` hangs —
    /// holding the lock makes such a submit wait, then fail its send
    /// against the dropped receiver and roll `pending` back.
    pub fn shutdown(&self) {
        let tx = self.tx.lock().unwrap();
        let _ = tx.send(Msg::Stop);
        if let Some(w) = self.worker.lock().unwrap().take() {
            let _ = w.join();
        }
    }
}

impl Drop for OfflineTuner {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::machine::AccessObserver;

    fn test_report() -> RunReport {
        RunReport {
            policy: "all-cxl".into(),
            wall_ns: 1e6,
            compute_ns: 4e5,
            stall_ns: 5e5,
            hit_ns: 1e5,
            migration_stall_ns: 0.0,
            accesses: 200_000,
            l3_hits: 0,
            l3_misses: 0,
            dram_misses: 0,
            cxl_misses: 0,
            promotions: 0,
            demotions: 0,
            ping_pongs: 0,
            migration_bytes: 0,
            peak_dram_bytes: 0,
            peak_cxl_bytes: 0,
            overlapped_ns: 0.0,
            lane_switches: 0,
            prefetch_issued: 0,
            prefetch_useful: 0,
        }
    }

    /// Synthetic profile: one hot object under a sampled DAMON.
    fn test_profile(function: &str) -> ProfileData {
        let cfg = Config::default();
        let base = crate::shim::intercept::MMAP_BASE;
        let obj = MemoryObject {
            id: crate::shim::object::ObjectId(0),
            start: base,
            bytes: 1 << 20,
            site: format!("{function}/x"),
            seq: 0,
            via_mmap: true,
        };
        let mut damon = Damon::new(&cfg.monitor, 4096, 1);
        damon.on_alloc(0.0, &obj);
        let mut t = 0.0;
        for i in 0..200_000u64 {
            t += 40.0;
            damon.on_access(t, base + (i * 64) % (1 << 20), 8, false);
        }
        ProfileData {
            function: function.into(),
            damon: Box::new(damon),
            objects: vec![obj],
            report: test_report(),
            trace_key: None,
        }
    }

    #[test]
    fn tuner_generates_hint_async() {
        let cfg = Config::default();
        let tuner = OfflineTuner::new(&cfg);
        tuner.submit(test_profile("f"));
        tuner.drain();
        let hint = tuner.hints().get("f").expect("hint generated");
        assert_eq!(hint.objects.len(), 1);
        assert!(tuner.hints().get("g").is_none());
        // provisioning off: the loop never ran
        assert_eq!(tuner.provision_metrics().counts(), (0, 0, 0));
    }

    #[test]
    fn drain_returns_after_worker_exit() {
        // regression: `drain` used to busy-wait on `pending`, which a
        // failed `submit` (worker gone, channel closed) left incremented
        // forever — a livelock. Now the failed send rolls `pending`
        // back and drain returns immediately.
        let tuner = OfflineTuner::new(&Config::default());
        tuner.shutdown();
        tuner.submit(test_profile("f"));
        tuner.drain(); // must not hang
        assert!(tuner.hints().get("f").is_none(), "dropped profile generates no hint");
        assert_eq!(tuner.processed.load(Ordering::SeqCst), 0);
    }

    #[test]
    fn shutdown_processes_queued_profiles_first() {
        let tuner = OfflineTuner::new(&Config::default());
        tuner.submit(test_profile("f"));
        // the stop message queues behind the profile
        tuner.shutdown();
        assert!(tuner.hints().get("f").is_some());
        tuner.drain();
        assert_eq!(tuner.processed.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn best_wall_keeps_minimum() {
        let cache = HintCache::default();
        cache.record_wall("f", 100.0);
        cache.record_wall("f", 80.0);
        cache.record_wall("f", 120.0);
        assert_eq!(cache.best_wall("f"), Some(80.0));
        cache.invalidate("f");
        assert_eq!(cache.best_wall("f"), None);
    }
}
