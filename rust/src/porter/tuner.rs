//! Offline tuner (Fig. 6 ④⑤): consumes profile data from engines,
//! produces cached placement hints for subsequent invocations.
//!
//! Runs on its own thread so hint generation never blocks the request
//! path — the paper's "all metrics are sent to an offline tuner". The
//! hint cache is the "placement hint consists only of metadata that can
//! be cached on each server".

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use crate::config::Config;
use crate::monitor::damon::Damon;
use crate::placement::hints::PlacementHint;
use crate::shim::object::MemoryObject;
use crate::sim::machine::RunReport;

/// Shared hint cache (per-deployment; the paper caches per server, but
/// hints are tiny metadata — one map serves the simulation).
#[derive(Default)]
pub struct HintCache {
    map: RwLock<HashMap<String, PlacementHint>>,
    /// Best observed wall time per function (SLO reference).
    best_wall: RwLock<HashMap<String, f64>>,
}

impl HintCache {
    pub fn get(&self, function: &str) -> Option<PlacementHint> {
        self.map.read().unwrap().get(function).cloned()
    }

    pub fn put(&self, hint: PlacementHint) {
        self.map.write().unwrap().insert(hint.function.clone(), hint);
    }

    pub fn invalidate(&self, function: &str) {
        self.map.write().unwrap().remove(function);
        self.best_wall.write().unwrap().remove(function);
    }

    pub fn record_wall(&self, function: &str, wall_ns: f64) {
        let mut best = self.best_wall.write().unwrap();
        let e = best.entry(function.to_string()).or_insert(wall_ns);
        if wall_ns < *e {
            *e = wall_ns;
        }
    }

    /// SLO reference latency for a function, if any run has completed.
    pub fn best_wall(&self, function: &str) -> Option<f64> {
        self.best_wall.read().unwrap().get(function).copied()
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Profile payload an engine ships after a monitored run.
pub struct ProfileData {
    pub function: String,
    pub damon: Box<Damon>,
    pub objects: Vec<MemoryObject>,
    pub report: RunReport,
}

enum Msg {
    Profile(ProfileData),
    Stop,
}

/// The tuner thread + its cache.
pub struct OfflineTuner {
    tx: Mutex<Sender<Msg>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    hints: Arc<HintCache>,
    pending: Arc<AtomicUsize>,
    pub processed: Arc<AtomicUsize>,
}

impl OfflineTuner {
    pub fn new(cfg: &Config) -> OfflineTuner {
        let (tx, rx) = channel::<Msg>();
        let hints = Arc::new(HintCache::default());
        let pending = Arc::new(AtomicUsize::new(0));
        let processed = Arc::new(AtomicUsize::new(0));
        let worker = {
            let hints = Arc::clone(&hints);
            let pending = Arc::clone(&pending);
            let processed = Arc::clone(&processed);
            let budget = cfg.porter.dram_budget_frac;
            let threshold = cfg.porter.hot_threshold;
            std::thread::Builder::new()
                .name("porter-tuner".into())
                .spawn(move || {
                    while let Ok(Msg::Profile(p)) = rx.recv() {
                        let hint = PlacementHint::generate(
                            &p.function,
                            &p.damon,
                            &p.objects,
                            budget,
                            threshold,
                        );
                        hints.put(hint);
                        pending.fetch_sub(1, Ordering::SeqCst);
                        processed.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .expect("spawn tuner")
        };
        OfflineTuner {
            tx: Mutex::new(tx),
            worker: Mutex::new(Some(worker)),
            hints,
            pending,
            processed,
        }
    }

    pub fn hints(&self) -> &HintCache {
        &self.hints
    }

    /// Ship a profile for asynchronous hint generation (Fig. 6 ④).
    pub fn submit(&self, data: ProfileData) {
        self.pending.fetch_add(1, Ordering::SeqCst);
        let _ = self.tx.lock().unwrap().send(Msg::Profile(data));
    }

    /// Wait until all submitted profiles are processed (tests/benches).
    pub fn drain(&self) {
        while self.pending.load(Ordering::SeqCst) > 0 {
            std::thread::yield_now();
        }
    }
}

impl Drop for OfflineTuner {
    fn drop(&mut self) {
        let _ = self.tx.lock().unwrap().send(Msg::Stop);
        if let Some(w) = self.worker.lock().unwrap().take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::machine::AccessObserver;

    #[test]
    fn tuner_generates_hint_async() {
        let cfg = Config::default();
        let tuner = OfflineTuner::new(&cfg);
        // synthetic profile: one hot object
        let base = crate::shim::intercept::MMAP_BASE;
        let obj = MemoryObject {
            id: crate::shim::object::ObjectId(0),
            start: base,
            bytes: 1 << 20,
            site: "f/x".into(),
            seq: 0,
            via_mmap: true,
        };
        let mut damon = Damon::new(&cfg.monitor, 4096, 1);
        damon.on_alloc(0.0, &obj);
        let mut t = 0.0;
        for i in 0..200_000u64 {
            t += 40.0;
            damon.on_access(t, base + (i * 64) % (1 << 20), 8, false);
        }
        let report = RunReport {
            policy: "all-cxl".into(),
            wall_ns: 1e6,
            compute_ns: 4e5,
            stall_ns: 5e5,
            hit_ns: 1e5,
            migration_stall_ns: 0.0,
            accesses: 200_000,
            l3_hits: 0,
            l3_misses: 0,
            dram_misses: 0,
            cxl_misses: 0,
            promotions: 0,
            demotions: 0,
            ping_pongs: 0,
            migration_bytes: 0,
            peak_dram_bytes: 0,
            peak_cxl_bytes: 0,
        };
        tuner.submit(ProfileData {
            function: "f".into(),
            damon: Box::new(damon),
            objects: vec![obj],
            report,
        });
        tuner.drain();
        let hint = tuner.hints().get("f").expect("hint generated");
        assert_eq!(hint.objects.len(), 1);
        assert!(tuner.hints().get("g").is_none());
    }

    #[test]
    fn best_wall_keeps_minimum() {
        let cache = HintCache::default();
        cache.record_wall("f", 100.0);
        cache.record_wall("f", 80.0);
        cache.record_wall("f", 120.0);
        assert_eq!(cache.best_wall("f"), Some(80.0));
        cache.invalidate("f");
        assert_eq!(cache.best_wall("f"), None);
    }
}
