//! Gateway: function registry + invocation intake (Fig. 6 ①).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use crate::porter::balancer::LeastLoaded;
use crate::porter::engine::InvocationOutcome;
use crate::porter::server::Server;
use crate::porter::tuner::OfflineTuner;
use crate::workloads::Workload;

/// A deployed function: the body plus the user-supplied speculation the
/// paper mentions (memory cap, SLO factor).
#[derive(Clone)]
pub struct FunctionSpec {
    pub name: String,
    /// The function body. Shared: workloads are immutable (`run(&self)`).
    pub body: Arc<dyn Workload + Send + Sync>,
    /// User-configured memory cap (the Lambda-style knob; informs the
    /// engine's DRAM grant).
    pub memory_cap_bytes: u64,
    /// Acceptable latency multiple over the function's best observed
    /// run (e.g. 1.10 = 10% over).
    pub slo_factor: f64,
}

impl FunctionSpec {
    pub fn new(name: &str, body: Arc<dyn Workload + Send + Sync>) -> FunctionSpec {
        FunctionSpec { name: name.to_string(), body, memory_cap_bytes: 4 << 30, slo_factor: 1.10 }
    }
}

/// Handle for an in-flight invocation.
pub struct InvocationTicket {
    pub id: u64,
    pub function: String,
    rx: Receiver<InvocationOutcome>,
}

impl InvocationTicket {
    /// Block until the function completes.
    pub fn wait(self) -> InvocationOutcome {
        self.rx.recv().expect("engine dropped without completing invocation")
    }
}

/// The deployment: registry + balancer + servers + tuner.
pub struct Gateway {
    functions: HashMap<String, FunctionSpec>,
    servers: Vec<Server>,
    balancer: LeastLoaded,
    pub tuner: Arc<OfflineTuner>,
    next_id: AtomicU64,
}

impl Gateway {
    pub fn new(cfg: &crate::config::Config) -> Gateway {
        let tuner = Arc::new(OfflineTuner::new(cfg));
        let servers = (0..cfg.porter.servers)
            .map(|i| Server::spawn(i, cfg, Arc::clone(&tuner)))
            .collect::<Vec<_>>();
        Gateway {
            functions: HashMap::new(),
            servers,
            balancer: LeastLoaded::default(),
            tuner,
            next_id: AtomicU64::new(1),
        }
    }

    /// Deploy (or update) a function. Updating clears its cached hint —
    /// new code means old profiles are stale.
    pub fn deploy(&mut self, spec: FunctionSpec) {
        self.tuner.hints().invalidate(&spec.name);
        self.functions.insert(spec.name.clone(), spec);
    }

    pub fn function(&self, name: &str) -> Option<&FunctionSpec> {
        self.functions.get(name)
    }

    /// Invoke a function (Fig. 6 ① → ②). Returns a ticket to await.
    pub fn invoke(&self, name: &str) -> Result<InvocationTicket, String> {
        let spec = self.functions.get(name).ok_or_else(|| format!("unknown function {name:?}"))?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let server = self.balancer.pick(&self.servers);
        let rx = self.servers[server].enqueue(id, spec.clone());
        Ok(InvocationTicket { id, function: name.to_string(), rx })
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    /// Queue depths per server (for balancer tests/metrics).
    pub fn queue_depths(&self) -> Vec<usize> {
        self.servers.iter().map(|s| s.load()).collect()
    }

    /// Stop all workers; in-flight invocations finish first.
    pub fn shutdown(self) {
        for s in self.servers {
            s.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::workloads::chameleon::Chameleon;

    fn small_config() -> Config {
        let mut cfg = Config::default();
        cfg.porter.servers = 2;
        cfg.porter.workers_per_server = 1;
        cfg
    }

    #[test]
    fn deploy_and_invoke_roundtrip() {
        let cfg = small_config();
        let mut gw = Gateway::new(&cfg);
        gw.deploy(FunctionSpec::new("chameleon", Arc::new(Chameleon::new(16, 8))));
        let t = gw.invoke("chameleon").unwrap();
        let outcome = t.wait();
        assert_eq!(outcome.function, "chameleon");
        assert!(outcome.report.wall_ns > 0.0);
        gw.shutdown();
    }

    #[test]
    fn unknown_function_rejected() {
        let cfg = small_config();
        let gw = Gateway::new(&cfg);
        assert!(gw.invoke("nope").is_err());
        gw.shutdown();
    }

    #[test]
    fn redeploy_invalidates_hint() {
        let cfg = small_config();
        let mut gw = Gateway::new(&cfg);
        gw.deploy(FunctionSpec::new("f", Arc::new(Chameleon::new(16, 8))));
        gw.invoke("f").unwrap().wait();
        // wait for the tuner to process the profile
        gw.tuner.drain();
        assert!(gw.tuner.hints().get("f").is_some());
        gw.deploy(FunctionSpec::new("f", Arc::new(Chameleon::new(8, 4))));
        assert!(gw.tuner.hints().get("f").is_none());
        gw.shutdown();
    }
}
