//! SLO accounting: per-function targets and violation rates.

use std::collections::HashMap;

use crate::porter::engine::InvocationOutcome;

/// Aggregates SLO outcomes across invocations.
#[derive(Debug, Default)]
pub struct SloTracker {
    per_function: HashMap<String, FnSlo>,
}

#[derive(Debug, Default, Clone)]
pub struct FnSlo {
    pub invocations: u64,
    /// Invocations that had a target in effect.
    pub judged: u64,
    pub violations: u64,
    pub total_wall_ns: f64,
}

impl FnSlo {
    pub fn violation_rate(&self) -> f64 {
        if self.judged == 0 {
            0.0
        } else {
            self.violations as f64 / self.judged as f64
        }
    }

    pub fn mean_wall_ns(&self) -> f64 {
        if self.invocations == 0 {
            0.0
        } else {
            self.total_wall_ns / self.invocations as f64
        }
    }
}

impl SloTracker {
    pub fn record(&mut self, outcome: &InvocationOutcome) {
        self.record_latency(&outcome.function, outcome.report.wall_ns, outcome.slo_target_ns);
    }

    /// Record a raw latency sample against an optional target. The
    /// cluster layer uses this for *end-to-end* latency (queue wait +
    /// service), which has no single `InvocationOutcome`.
    pub fn record_latency(&mut self, function: &str, latency_ns: f64, target_ns: Option<f64>) {
        let e = self.per_function.entry(function.to_string()).or_default();
        e.invocations += 1;
        e.total_wall_ns += latency_ns;
        if let Some(t) = target_ns {
            e.judged += 1;
            if latency_ns > t {
                e.violations += 1;
            }
        }
    }

    pub fn get(&self, function: &str) -> Option<&FnSlo> {
        self.per_function.get(function)
    }

    pub fn overall_violation_rate(&self) -> f64 {
        // detlint: allow(D1, reason = "u64 sum is order-insensitive")
        let judged: u64 = self.per_function.values().map(|f| f.judged).sum();
        // detlint: allow(D1, reason = "u64 sum is order-insensitive")
        let viol: u64 = self.per_function.values().map(|f| f.violations).sum();
        if judged == 0 {
            0.0
        } else {
            viol as f64 / judged as f64
        }
    }

    pub fn functions(&self) -> impl Iterator<Item = (&str, &FnSlo)> {
        // detlint: allow(D1, reason = "sole consumer is an order-insensitive u64 violation count (cluster finish)")
        self.per_function.iter().map(|(k, v)| (k.as_str(), v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::machine::RunReport;

    fn outcome(function: &str, wall: f64, target: Option<f64>) -> InvocationOutcome {
        InvocationOutcome {
            id: 0,
            function: function.into(),
            report: RunReport {
                policy: "t".into(),
                wall_ns: wall,
                compute_ns: wall,
                stall_ns: 0.0,
                hit_ns: 0.0,
                migration_stall_ns: 0.0,
                accesses: 0,
                l3_hits: 0,
                l3_misses: 0,
                dram_misses: 0,
                cxl_misses: 0,
                promotions: 0,
                demotions: 0,
                ping_pongs: 0,
                migration_bytes: 0,
                peak_dram_bytes: 0,
                peak_cxl_bytes: 0,
                overlapped_ns: 0.0,
                lane_switches: 0,
                prefetch_issued: 0,
                prefetch_useful: 0,
            },
            checksum: 0,
            used_hint: false,
            profiled: false,
            slo_target_ns: target,
            sandbox: crate::shim::SandboxImage::default(),
            trace_replayed: false,
            trace_recorded_bytes: 0,
            host_micros: 0,
            telemetry: None,
        }
    }

    #[test]
    fn violation_rate_counts_only_judged() {
        let mut t = SloTracker::default();
        t.record(&outcome("f", 100.0, None)); // first run: no target
        t.record(&outcome("f", 100.0, Some(110.0))); // met
        t.record(&outcome("f", 150.0, Some(110.0))); // violated
        let f = t.get("f").unwrap();
        assert_eq!(f.invocations, 3);
        assert_eq!(f.judged, 2);
        assert_eq!(f.violations, 1);
        assert!((f.violation_rate() - 0.5).abs() < 1e-9);
        assert!((t.overall_violation_rate() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn unknown_function_none() {
        let t = SloTracker::default();
        assert!(t.get("nope").is_none());
        assert_eq!(t.overall_violation_rate(), 0.0);
    }
}
