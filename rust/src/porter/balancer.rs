//! Load balancer (Fig. 6's "load balancer (e.g. Kubernetes)"):
//! least-loaded routing over a pool of load-reporting targets.
//!
//! [`LeastLoaded`] is generic over [`Loaded`] so the same policy routes
//! invocations across a server pool (the single-machine Porter path) and
//! across fleet nodes (`cluster::`'s inner server pick).

use crate::porter::server::Server;

/// Anything the balancer can route to.
pub trait Loaded {
    /// Queued + running invocations (lower is better).
    fn load(&self) -> usize;
}

impl Loaded for Server {
    fn load(&self) -> usize {
        Server::load(self)
    }
}

/// Route to the target with the fewest queued + running invocations.
///
/// Tie-breaking is true round-robin over the minimum-load set: the scan
/// cursor advances *past the picked target*, so repeated picks visit the
/// tied targets in cyclic order. (The previous cursor advanced by one
/// per call regardless of the pick, which skewed tied subsets — e.g.
/// with loads `[3, 1, 1]` it routed two thirds of the traffic to the
/// first tied server.)
#[derive(Debug, Default)]
pub struct LeastLoaded {
    rr: std::sync::atomic::AtomicUsize,
}

impl LeastLoaded {
    pub fn pick<T: Loaded>(&self, servers: &[T]) -> usize {
        assert!(!servers.is_empty());
        let n = servers.len();
        // fetch_add keeps concurrent pickers on distinct start offsets
        // (Gateway::invoke races several threads through here)...
        let start = self.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % n;
        let mut best = start;
        let mut best_load = servers[start].load();
        for off in 1..n {
            let i = (start + off) % n;
            let l = servers[i].load();
            if l < best_load {
                best = i;
                best_load = l;
            }
        }
        // ...and advancing past the pick makes the next scan start
        // after it, so equally-loaded targets are visited in cyclic
        // order (the old cursor skewed tied subsets, e.g. two thirds
        // of [3, 1, 1]'s traffic went to the first tied server). Under
        // concurrency the store can lose a race, which only perturbs
        // the cursor, never the least-loaded invariant.
        self.rr.store(best + 1, std::sync::atomic::Ordering::Relaxed);
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::porter::tuner::OfflineTuner;
    use std::sync::Arc;

    struct Fixed(usize);

    impl Loaded for Fixed {
        fn load(&self) -> usize {
            self.0
        }
    }

    #[test]
    fn picks_least_loaded() {
        let mut cfg = Config::default();
        cfg.porter.workers_per_server = 1;
        let tuner = Arc::new(OfflineTuner::new(&cfg));
        let servers: Vec<Server> =
            (0..3).map(|i| Server::spawn(i, &cfg, Arc::clone(&tuner))).collect();
        let lb = LeastLoaded::default();
        // all empty: round-robins over servers
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            seen.insert(lb.pick(&servers));
        }
        assert_eq!(seen.len(), 3);
        for s in servers {
            s.shutdown();
        }
    }

    #[test]
    fn tied_subset_rotates_fairly() {
        // loads [3, 1, 1]: all traffic goes to the tied {1, 2}, split
        // evenly (the pre-fix cursor gave server 1 two thirds)
        let servers = vec![Fixed(3), Fixed(1), Fixed(1)];
        let lb = LeastLoaded::default();
        let mut counts = [0usize; 3];
        for _ in 0..10 {
            counts[lb.pick(&servers)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[1], 5);
        assert_eq!(counts[2], 5);
    }

    #[test]
    fn single_target_always_zero() {
        let lb = LeastLoaded::default();
        for _ in 0..5 {
            assert_eq!(lb.pick(&[Fixed(7)]), 0);
        }
    }
}
