//! Load balancer (Fig. 6's "load balancer (e.g. Kubernetes)"):
//! least-loaded routing over the server pool.

use crate::porter::server::Server;

/// Route to the server with the fewest queued + running invocations;
/// ties break round-robin so idle pools still spread work.
#[derive(Debug, Default)]
pub struct LeastLoaded {
    rr: std::sync::atomic::AtomicUsize,
}

impl LeastLoaded {
    pub fn pick(&self, servers: &[Server]) -> usize {
        assert!(!servers.is_empty());
        let start = self.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % servers.len();
        let mut best = start;
        let mut best_load = servers[start].load();
        for off in 1..servers.len() {
            let i = (start + off) % servers.len();
            let l = servers[i].load();
            if l < best_load {
                best = i;
                best_load = l;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::porter::tuner::OfflineTuner;
    use std::sync::Arc;

    #[test]
    fn picks_least_loaded() {
        let mut cfg = Config::default();
        cfg.porter.workers_per_server = 1;
        let tuner = Arc::new(OfflineTuner::new(&cfg));
        let servers: Vec<Server> =
            (0..3).map(|i| Server::spawn(i, &cfg, Arc::clone(&tuner))).collect();
        let lb = LeastLoaded::default();
        // all empty: round-robins over servers
        let mut seen = std::collections::HashSet::new();
        for _ in 0..3 {
            seen.insert(lb.pick(&servers));
        }
        assert_eq!(seen.len(), 3);
        for s in servers {
            s.shutdown();
        }
    }
}
