//! Per-server system-load tracking (Fig. 6 ⑥): the engine consults
//! current memory footprint/pressure when deciding placements, and
//! invocations reserve/release tier capacity as they start/finish.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::MachineConfig;
use crate::mem::tier::TierKind;

/// Lock-free occupancy accounting for one server's two tiers.
#[derive(Debug)]
pub struct SystemLoad {
    dram_capacity: u64,
    cxl_capacity: u64,
    dram_used: AtomicU64,
    cxl_used: AtomicU64,
}

/// A reservation; returned to the load tracker on drop.
#[derive(Debug)]
pub struct Reservation<'a> {
    load: &'a SystemLoad,
    pub dram: u64,
    pub cxl: u64,
}

impl SystemLoad {
    pub fn new(cfg: &MachineConfig) -> SystemLoad {
        SystemLoad {
            dram_capacity: cfg.dram_bytes,
            cxl_capacity: cfg.cxl_bytes,
            dram_used: AtomicU64::new(0),
            cxl_used: AtomicU64::new(0),
        }
    }

    pub fn occupancy(&self, tier: TierKind) -> f64 {
        match tier {
            TierKind::Dram => {
                self.dram_used.load(Ordering::Relaxed) as f64 / self.dram_capacity as f64
            }
            TierKind::Cxl => {
                self.cxl_used.load(Ordering::Relaxed) as f64 / self.cxl_capacity as f64
            }
        }
    }

    pub fn free(&self, tier: TierKind) -> u64 {
        match tier {
            TierKind::Dram => {
                self.dram_capacity.saturating_sub(self.dram_used.load(Ordering::Relaxed))
            }
            TierKind::Cxl => {
                self.cxl_capacity.saturating_sub(self.cxl_used.load(Ordering::Relaxed))
            }
        }
    }

    /// Reserve up to `dram_wanted` DRAM (granted as available) and the
    /// remainder of `footprint` in CXL.
    pub fn reserve(&self, footprint: u64, dram_wanted: u64) -> Reservation<'_> {
        let dram = self.try_take(&self.dram_used, self.dram_capacity, dram_wanted.min(footprint));
        let cxl = self.try_take(&self.cxl_used, self.cxl_capacity, footprint - dram);
        Reservation { load: self, dram, cxl }
    }

    fn try_take(&self, used: &AtomicU64, capacity: u64, want: u64) -> u64 {
        let mut cur = used.load(Ordering::Relaxed);
        loop {
            let granted = want.min(capacity.saturating_sub(cur));
            if granted == 0 {
                return 0;
            }
            match used.compare_exchange_weak(
                cur,
                cur + granted,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return granted,
                Err(actual) => cur = actual,
            }
        }
    }
}

impl Drop for Reservation<'_> {
    fn drop(&mut self) {
        self.load.dram_used.fetch_sub(self.dram, Ordering::Relaxed);
        self.load.cxl_used.fetch_sub(self.cxl, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> MachineConfig {
        let mut c = MachineConfig::default();
        c.dram_bytes = 1000;
        c.cxl_bytes = 10_000;
        c
    }

    #[test]
    fn reserve_and_release() {
        let load = SystemLoad::new(&cfg());
        {
            let r = load.reserve(600, 600);
            assert_eq!(r.dram, 600);
            assert_eq!(r.cxl, 0);
            assert!((load.occupancy(TierKind::Dram) - 0.6).abs() < 1e-9);
        }
        assert_eq!(load.occupancy(TierKind::Dram), 0.0);
    }

    #[test]
    fn overflow_spills_to_cxl() {
        let load = SystemLoad::new(&cfg());
        let _a = load.reserve(900, 900);
        let b = load.reserve(500, 500);
        assert_eq!(b.dram, 100); // only 100 DRAM left
        assert_eq!(b.cxl, 400);
    }

    #[test]
    fn concurrent_reservations_never_oversubscribe() {
        let load = std::sync::Arc::new(SystemLoad::new(&cfg()));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let load = std::sync::Arc::clone(&load);
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        let r = load.reserve(77, 77);
                        assert!(r.dram + r.cxl <= 77);
                        drop(r);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(load.free(TierKind::Dram), 1000);
        assert_eq!(load.free(TierKind::Cxl), 10_000);
    }
}
