//! The Porter engine: per-invocation placement decision + execution
//! (Fig. 6 ③⑥⑦).
//!
//! Decision tree per invocation:
//! * **Hint cached** → static placement by hint (hot→DRAM, cold→CXL)
//!   within the DRAM the server can actually grant right now (⑥), plus
//!   the background promotion/demotion thread (⑦).
//! * **No hint (first invocation / redeploy)** → provision local DRAM
//!   for the best SLO guarantee, load permitting (③), and attach the
//!   shim + DAMON profiler; metrics ship to the offline tuner (④).

use crate::config::{
    Config, LanesConfig, MachineConfig, MigrationConfig, MonitorConfig, PorterConfig,
    TelemetryConfig, TraceConfig,
};
use crate::mem::migrate::MigrationEngine;
use crate::mem::tier::TierKind;
use crate::monitor::damon::Damon;
use crate::placement::policies::{FirstTouchDram, HintedPlacer};
use crate::porter::gateway::FunctionSpec;
use crate::porter::sysload::SystemLoad;
use crate::porter::tuner::{OfflineTuner, ProfileData};
use crate::sim::machine::{Machine, RunReport};
use crate::trace::{TraceKey, TraceStore};
use crate::util::hosttime::HostTimer;

/// Engine-side slice of the config (cloneable into worker threads).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub machine: MachineConfig,
    pub monitor: MonitorConfig,
    pub porter: PorterConfig,
    pub migration: MigrationConfig,
    pub trace: TraceConfig,
    pub telemetry: TelemetryConfig,
    pub lanes: LanesConfig,
}

impl From<&Config> for EngineConfig {
    fn from(cfg: &Config) -> EngineConfig {
        EngineConfig {
            machine: cfg.machine.clone(),
            monitor: cfg.monitor.clone(),
            porter: cfg.porter.clone(),
            migration: cfg.migration.clone(),
            trace: cfg.trace.clone(),
            telemetry: cfg.telemetry.clone(),
            lanes: cfg.lanes.clone(),
        }
    }
}

/// What the gateway hands back for one completed invocation.
#[derive(Debug)]
pub struct InvocationOutcome {
    pub id: u64,
    pub function: String,
    pub report: RunReport,
    pub checksum: u64,
    /// Whether a cached hint drove placement.
    pub used_hint: bool,
    /// Whether this run was profiled (first invocation path).
    pub profiled: bool,
    /// SLO target in effect before the run (best wall × slo_factor).
    pub slo_target_ns: Option<f64>,
    /// Shim-captured sandbox state (object list + per-tier residency)
    /// — what a warm pool keeps alive and a snapshot persists.
    pub sandbox: crate::shim::SandboxImage,
    /// This invocation replayed a stored Trace-IR stream instead of
    /// executing the function body.
    pub trace_replayed: bool,
    /// Size of the canonical trace this run recorded into the
    /// `TraceStore` (0 when it replayed or ran live-only).
    pub trace_recorded_bytes: u64,
    /// Host-side execution time of the simulation (engine overhead
    /// accounting, not part of the simulated metric).
    pub host_micros: u64,
    /// Machine-level telemetry collected during the run (migration
    /// epochs, phase markers); `None` unless `[telemetry]` is enabled.
    pub telemetry: Option<crate::telemetry::TelemetrySink>,
}

impl InvocationOutcome {
    pub fn slo_met(&self) -> Option<bool> {
        self.slo_target_ns.map(|t| self.report.wall_ns <= t)
    }
}

/// Execute one invocation on a worker thread.
pub fn run_invocation(
    id: u64,
    spec: &FunctionSpec,
    cfg: &EngineConfig,
    sysload: &SystemLoad,
    tuner: &OfflineTuner,
) -> InvocationOutcome {
    // Host stopwatch, NOT simulation time: feeds only `host_micros`,
    // which RunReport equality and the determinism token never see.
    let started = HostTimer::start();
    let slo_target_ns = tuner.hints().best_wall(&spec.name).map(|w| w * spec.slo_factor);
    let hint = tuner.hints().get(&spec.name);
    let footprint = spec.body.footprint_hint().max(cfg.machine.page_bytes);

    // ⑥ how much DRAM do we *want* and can the server grant?
    let dram_wanted = match &hint {
        Some(h) => h.hot_bytes().max(cfg.machine.page_bytes).min(spec.memory_cap_bytes),
        // first invocation: all of it, for the best SLO guarantee
        None => footprint.min(spec.memory_cap_bytes),
    };
    let reservation = sysload.reserve(footprint, dram_wanted);

    // The invocation's machine sees only the granted capacities.
    let mut mcfg = cfg.machine.clone();
    mcfg.dram_bytes = reservation.dram.max(cfg.machine.page_bytes);
    mcfg.cxl_bytes = cfg.machine.cxl_bytes; // capacity tier is plentiful

    let dram_pressure = sysload.occupancy(TierKind::Dram);
    let (mut machine, used_hint, profiled) = match hint {
        Some(h) => {
            let mut placer = HintedPlacer::new(h);
            // unknown objects: DRAM if the server has headroom (SLO-safe
            // default), CXL under pressure
            placer.unknown_tier = if dram_pressure < cfg.porter.dram_pressure_high {
                TierKind::Dram
            } else {
                TierKind::Cxl
            };
            (Machine::new(&mcfg, Box::new(placer)), true, false)
        }
        None => {
            let pressure_limit = if cfg.porter.first_touch_dram {
                cfg.porter.dram_pressure_high
            } else {
                0.0
            };
            let placer = FirstTouchDram { pressure: pressure_limit.max(0.01) };
            let machine = Machine::new(&mcfg, Box::new(placer));
            (machine, false, true)
        }
    };
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    // `[lanes]`: per-invocation lane scheduler + optional prefetcher.
    // The effective lane count is capped by the workload's annotated
    // parallelism, so sequential functions stay on the scalar path's
    // arithmetic shape (K lanes with no switches = serial).
    if cfg.lanes.enabled {
        machine.set_lanes(cfg.lanes.max_lanes.min(spec.body.lane_hints()).max(1));
        if cfg.lanes.prefetch {
            machine.set_prefetcher(cfg.lanes.prefetch_degree, cfg.lanes.prefetch_distance);
        }
    }
    if profiled {
        machine.attach_observer(Box::new(Damon::new(
            &cfg.monitor,
            cfg.machine.page_bytes,
            0xDA110 ^ id,
        )));
    }
    // ⑦ runtime promotion/demotion thread: the epoch-driven engine,
    // per-invocation (a fresh engine per run — no stale hotness leaks
    // across invocations on the same server), ticked every aggregation
    // interval and closing an epoch every `migration.epoch_ticks` ticks.
    // Legacy `[porter]` migration knobs flow in as fallbacks.
    if cfg.porter.migration_enabled {
        let mig_cfg = cfg.migration.with_porter_fallbacks(&cfg.porter);
        if let Some(engine) = MigrationEngine::from_config(&mig_cfg) {
            machine.set_migrator(Box::new(engine));
        }
    }
    if cfg.telemetry.enabled {
        machine.set_telemetry(crate::telemetry::TelemetrySink::new(cfg.telemetry.buffer_bytes));
    }

    // run the function: replay the canonical Trace-IR stream when one
    // exists (record-once/replay-many), else execute live — in
    // recording mode, so this run's stream becomes the canonical trace
    // for every later invocation of the same (workload, size) pair.
    // `[trace] live_execution = true` restores unconditional
    // re-execution.
    let use_replay = cfg.trace.enabled && !cfg.trace.live_execution;
    // the canonical stream's store key doubles as the provisioning
    // loop's what-if handle: it rides along on the profile shipped to
    // the tuner so demand curves can replay the same recording
    let trace_key = if use_replay {
        Some(TraceKey::of(spec.body.as_ref(), cfg.machine.page_bytes))
    } else {
        None
    };
    let mut trace_replayed = false;
    let mut trace_recorded_bytes = 0u64;
    let (checksum, objects) = if use_replay {
        let store = TraceStore::global();
        let key = trace_key.clone().expect("use_replay implies a key");
        match store.get(&key) {
            Some(trace) => {
                machine.replay(&trace);
                trace_replayed = true;
                (trace.checksum, trace.objects.clone())
            }
            None => {
                let mut env =
                    crate::shim::env::Env::new_recording(cfg.machine.page_bytes, &mut machine);
                let checksum = spec.body.run(&mut env);
                let objects: Vec<_> = env.objects().to_vec();
                let mut trace = env.finish_recording().expect("recording env");
                trace.workload = spec.body.name().to_string();
                trace.checksum = checksum;
                trace_recorded_bytes = trace.encoded_bytes();
                store.insert(key, trace, cfg.trace.max_cached);
                (checksum, objects)
            }
        }
    } else {
        let mut env = crate::shim::env::Env::new(cfg.machine.page_bytes, &mut machine);
        let checksum = spec.body.run(&mut env);
        let objects: Vec<_> = env.objects().to_vec();
        drop(env);
        (checksum, objects)
    };
    let report = machine.report();
    // record the wall time BEFORE shipping the profile: the tuner's
    // provisioning loop reads best_wall for SLO floors, and ordering it
    // after submit would race the worker thread (nondeterministic
    // floors; the fleet-simulation determinism token would flake)
    tuner.hints().record_wall(&spec.name, report.wall_ns);
    // sandbox state capture: the object list plus where the run's
    // working set peaked — the lifecycle layer keeps/snapshots this.
    // ④ the profiled path also ships the objects to the offline tuner,
    // so only it pays a clone (one-off per function); the hot serving
    // path consumes the vec without copying.
    let sandbox = if profiled {
        let sandbox = crate::shim::SandboxImage::capture(
            &objects,
            report.peak_dram_bytes,
            report.peak_cxl_bytes,
        );
        if let Some(obs) = machine.take_observers().pop() {
            if let Ok(damon) = obs.into_any().downcast::<Damon>() {
                tuner.submit(ProfileData {
                    function: spec.name.clone(),
                    damon,
                    objects,
                    report: report.clone(),
                    trace_key,
                });
            }
        }
        sandbox
    } else {
        crate::shim::SandboxImage::capture_owned(
            objects,
            report.peak_dram_bytes,
            report.peak_cxl_bytes,
        )
    };
    drop(reservation);

    InvocationOutcome {
        id,
        function: spec.name.clone(),
        report,
        checksum,
        used_hint,
        profiled,
        slo_target_ns,
        sandbox,
        trace_replayed,
        trace_recorded_bytes,
        host_micros: started.elapsed_micros(),
        telemetry: machine.take_telemetry(),
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::workloads::kvstore::KvStore;

    fn setup() -> (EngineConfig, Arc<SystemLoad>, OfflineTuner) {
        let cfg = Config::default();
        let ecfg = EngineConfig::from(&cfg);
        let sysload = Arc::new(SystemLoad::new(&cfg.machine));
        let tuner = OfflineTuner::new(&cfg);
        (ecfg, sysload, tuner)
    }

    #[test]
    fn first_invocation_profiles_then_hint_is_used() {
        let (ecfg, sysload, tuner) = setup();
        let spec = FunctionSpec::new("kv", Arc::new(KvStore::new(50_000, 100_000)));

        let first = run_invocation(1, &spec, &ecfg, &sysload, &tuner);
        assert!(first.profiled);
        assert!(!first.used_hint);
        assert!(first.slo_target_ns.is_none());
        // the shim captured the sandbox image alongside the profile
        assert!(!first.sandbox.objects.is_empty());
        assert!(first.sandbox.resident_bytes() > 1);
        assert_eq!(
            first.sandbox.heap_bytes + first.sandbox.mmap_bytes,
            first.sandbox.objects.iter().map(|o| o.bytes).sum::<u64>()
        );

        tuner.drain();
        assert!(tuner.hints().get("kv").is_some());

        let second = run_invocation(2, &spec, &ecfg, &sysload, &tuner);
        assert!(second.used_hint);
        assert!(!second.profiled);
        assert!(second.slo_target_ns.is_some());
        // identical computation regardless of placement
        assert_eq!(first.checksum, second.checksum);
    }

    #[test]
    fn hinted_run_close_to_first_touch_dram_run() {
        // With ample DRAM, the first run is essentially all-DRAM; the
        // hinted run keeps the hot set in DRAM so it should be within a
        // modest factor.
        let (ecfg, sysload, tuner) = setup();
        let spec = FunctionSpec::new("kv", Arc::new(KvStore::new(100_000, 200_000)));
        let first = run_invocation(1, &spec, &ecfg, &sysload, &tuner);
        tuner.drain();
        let second = run_invocation(2, &spec, &ecfg, &sysload, &tuner);
        let ratio = second.report.wall_ns / first.report.wall_ns;
        assert!(ratio < 1.6, "hinted run {ratio:.2}x the DRAM-first run");
    }

    #[test]
    fn migration_engine_promotes_on_tiny_dram_grant() {
        // A server that can grant almost no DRAM forces the footprint
        // into CXL; with the engine enabled, heatmap samples must drive
        // promotions of the hot pages back into the granted DRAM.
        let run = |policy: &str| {
            let (mut ecfg, _, tuner) = setup();
            ecfg.machine.dram_bytes = 128 * ecfg.machine.page_bytes;
            ecfg.migration.policy = policy.to_string();
            ecfg.migration.epoch_ticks = 1;
            let sysload = Arc::new(SystemLoad::new(&ecfg.machine));
            let spec = FunctionSpec::new("kv", Arc::new(KvStore::new(50_000, 100_000)));
            run_invocation(1, &spec, &ecfg, &sysload, &tuner)
        };
        for policy in ["naive", "tpp", "hybrid"] {
            let out = run(policy);
            assert!(
                out.report.promotions > 0,
                "{policy}: heatmap samples should drive promotions"
            );
            assert_eq!(
                out.report.migration_bytes,
                (out.report.promotions + out.report.demotions) * 4096,
                "{policy}: migration bytes must match applied moves"
            );
        }
        let off = {
            let (mut ecfg, _, tuner) = setup();
            ecfg.machine.dram_bytes = 128 * ecfg.machine.page_bytes;
            ecfg.migration.policy = "none".to_string();
            let sysload = Arc::new(SystemLoad::new(&ecfg.machine));
            let spec = FunctionSpec::new("kv", Arc::new(KvStore::new(50_000, 100_000)));
            run_invocation(1, &spec, &ecfg, &sysload, &tuner)
        };
        assert_eq!(off.report.promotions, 0);
    }

    #[test]
    fn trace_store_replays_repeat_invocations() {
        let (ecfg, sysload, tuner) = setup();
        // params unique to this test so the first run is a recording
        // regardless of test interleaving in the shared process store
        let spec = FunctionSpec::new("kv", Arc::new(KvStore::new(30_000, 60_000)));
        let first = run_invocation(1, &spec, &ecfg, &sysload, &tuner);
        let second = run_invocation(2, &spec, &ecfg, &sysload, &tuner);
        assert!(second.trace_replayed, "second invocation must replay the stored trace");
        assert_eq!(second.trace_recorded_bytes, 0);
        assert_eq!(first.checksum, second.checksum);
        // escape hatch: live execution bypasses the store both ways
        let mut live_cfg = ecfg.clone();
        live_cfg.trace.live_execution = true;
        let third = run_invocation(3, &spec, &live_cfg, &sysload, &tuner);
        assert!(!third.trace_replayed);
        assert_eq!(third.trace_recorded_bytes, 0);
        assert_eq!(third.checksum, first.checksum, "live and replayed runs agree");
    }

    #[test]
    fn telemetry_collects_machine_events_without_perturbing_the_report() {
        let (mut ecfg, _, tuner) = setup();
        // tiny DRAM grant + 1-tick epochs: the migration engine must act
        ecfg.machine.dram_bytes = 128 * ecfg.machine.page_bytes;
        ecfg.migration.epoch_ticks = 1;
        let sysload = Arc::new(SystemLoad::new(&ecfg.machine));
        let spec = FunctionSpec::new("kv", Arc::new(KvStore::new(40_000, 80_000)));
        let base = run_invocation(1, &spec, &ecfg, &sysload, &tuner);
        assert!(base.telemetry.is_none(), "default-off: no sink attached");
        ecfg.telemetry.enabled = true;
        let out = run_invocation(2, &spec, &ecfg, &sysload, &tuner);
        assert_eq!(out.report, base.report, "instrumented replay must match exactly");
        let sink = out.telemetry.expect("enabled run hands its sink back");
        assert!(sink.total_events() > 0);
        assert!(sink.kind_counts().contains_key("machine_epoch"));
    }

    #[test]
    fn pressure_pushes_first_touch_to_cxl() {
        let (mut ecfg, _, tuner) = setup();
        ecfg.machine.dram_bytes = 64 * ecfg.machine.page_bytes; // tiny server DRAM
        let sysload = Arc::new(SystemLoad::new(&ecfg.machine));
        let spec = FunctionSpec::new("kv", Arc::new(KvStore::new(50_000, 50_000)));
        let out = run_invocation(1, &spec, &ecfg, &sysload, &tuner);
        // footprint ≫ DRAM: most pages must live in CXL
        assert!(out.report.peak_cxl_bytes > out.report.peak_dram_bytes);
    }
}
