//! Porter — the middleware between the serverless platform and the
//! CXL-enabled tiered memory system (§4, Fig. 6).
//!
//! Control path, numbered as in the paper's Fig. 6:
//!
//! 1. a user invokes a function via the [`gateway`];
//! 2. the [`balancer`] routes the invocation to a server, where it is
//!    pushed onto a local queue fetched asynchronously by the engine;
//! 3. first-time invocations are provisioned local DRAM for the best SLO
//!    guarantee (load permitting), while the attached shim + DAMON
//!    profile the run;
//! 4. metrics flow to the offline [`tuner`];
//! 5. the tuner emits a per-function *placement hint* (cacheable
//!    metadata);
//! 6. subsequent invocations combine the hint with current
//!    [`sysload`] to place memory objects;
//! 7. a background migration thread promotes/demotes pages during
//!    execution.
//!
//! Everything is plain threads + channels: the offline image has no
//! tokio, and a queue-per-server worker pool is exactly what the paper's
//! engine describes anyway.

pub mod balancer;
pub mod engine;
pub mod gateway;
pub mod server;
pub mod slo;
pub mod sysload;
pub mod tuner;

pub use engine::{EngineConfig, InvocationOutcome};
pub use gateway::{FunctionSpec, Gateway, InvocationTicket};
pub use tuner::{HintCache, OfflineTuner};
