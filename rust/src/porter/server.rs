//! Per-server queue + engine worker pool (Fig. 6 ②: "invocation
//! payloads … are pushed into a local queue, which are fetched by an
//! engine asynchronously").

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::Config;
use crate::metrics::Registry;
use crate::porter::engine::{run_invocation, EngineConfig, InvocationOutcome};
use crate::porter::gateway::FunctionSpec;
use crate::porter::sysload::SystemLoad;
use crate::porter::tuner::OfflineTuner;

enum Job {
    Invoke { id: u64, spec: FunctionSpec, done: Sender<InvocationOutcome> },
    Stop,
}

/// One simulated server: queue, engine workers, its own memory-load
/// accounting, and a metrics registry the workers feed (invocation and
/// migration counters, virtual-latency histogram).
pub struct Server {
    pub index: usize,
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    outstanding: Arc<AtomicUsize>,
    pub sysload: Arc<SystemLoad>,
    pub metrics: Arc<Registry>,
}

impl Server {
    pub fn spawn(index: usize, cfg: &Config, tuner: Arc<OfflineTuner>) -> Server {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let outstanding = Arc::new(AtomicUsize::new(0));
        let sysload = Arc::new(SystemLoad::new(&cfg.machine));
        let metrics = Arc::new(Registry::default());
        let engine_cfg = EngineConfig::from(cfg);
        let workers = (0..cfg.porter.workers_per_server)
            .map(|w| {
                let rx = Arc::clone(&rx);
                let outstanding = Arc::clone(&outstanding);
                let sysload = Arc::clone(&sysload);
                let tuner = Arc::clone(&tuner);
                let metrics = Arc::clone(&metrics);
                let engine_cfg = engine_cfg.clone();
                std::thread::Builder::new()
                    .name(format!("porter-s{index}w{w}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(Job::Invoke { id, spec, done }) => {
                                let outcome =
                                    run_invocation(id, &spec, &engine_cfg, &sysload, &tuner);
                                let r = &outcome.report;
                                metrics.counter("invocations").inc();
                                metrics.counter("migration.promotions").add(r.promotions);
                                metrics.counter("migration.demotions").add(r.demotions);
                                metrics.counter("migration.ping_pongs").add(r.ping_pongs);
                                metrics.counter("migration.bytes").add(r.migration_bytes);
                                if outcome.trace_replayed {
                                    metrics.counter("trace.replays").inc();
                                } else if outcome.trace_recorded_bytes > 0 {
                                    metrics.counter("trace.records").inc();
                                    metrics
                                        .counter("trace.bytes")
                                        .add(outcome.trace_recorded_bytes);
                                }
                                metrics.histogram("invocation.wall_ns").record(r.wall_ns as u64);
                                outstanding.fetch_sub(1, Ordering::Relaxed);
                                let _ = done.send(outcome);
                            }
                            Ok(Job::Stop) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Server { index, tx, workers, outstanding, sysload, metrics }
    }

    /// Push an invocation; returns the completion channel.
    pub fn enqueue(&self, id: u64, spec: FunctionSpec) -> Receiver<InvocationOutcome> {
        let (done_tx, done_rx) = channel();
        self.outstanding.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Job::Invoke { id, spec, done: done_tx }).expect("server stopped");
        done_rx
    }

    /// Queued + running invocations (balancer signal).
    pub fn load(&self) -> usize {
        self.outstanding.load(Ordering::Relaxed)
    }

    pub fn shutdown(self) {
        for _ in &self.workers {
            let _ = self.tx.send(Job::Stop);
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::porter::gateway::FunctionSpec;
    use crate::workloads::json_ser::JsonSer;

    #[test]
    fn serves_jobs_in_parallel_workers() {
        let mut cfg = Config::default();
        cfg.porter.workers_per_server = 4;
        let tuner = Arc::new(OfflineTuner::new(&cfg));
        let server = Server::spawn(0, &cfg, tuner);
        let spec = FunctionSpec::new("json", Arc::new(JsonSer::new(50)));
        let rxs: Vec<_> = (0..8).map(|i| server.enqueue(i, spec.clone())).collect();
        for rx in rxs {
            let out = rx.recv().unwrap();
            assert_eq!(out.function, "json");
        }
        assert_eq!(server.load(), 0);
        assert_eq!(server.metrics.counter("invocations").get(), 8);
        assert_eq!(server.metrics.histogram("invocation.wall_ns").count(), 8);
        // record-once/replay-many: every job either recorded the
        // canonical trace or replayed it (racing workers may record
        // more than once; repeats must replay)
        let records = server.metrics.counter("trace.records").get();
        let replays = server.metrics.counter("trace.replays").get();
        assert_eq!(records + replays, 8);
        assert!(replays > 0, "repeat invocations must replay the stored trace");
        server.shutdown();
    }

    #[test]
    fn migration_counters_flow_into_server_metrics() {
        // a DRAM-starved server running a kvstore must log promotions
        let mut cfg = Config::default();
        cfg.porter.workers_per_server = 1;
        cfg.machine.dram_bytes = 128 * cfg.machine.page_bytes;
        cfg.migration.epoch_ticks = 1;
        let tuner = Arc::new(OfflineTuner::new(&cfg));
        let server = Server::spawn(0, &cfg, tuner);
        let store = crate::workloads::kvstore::KvStore::new(50_000, 100_000);
        let spec = FunctionSpec::new("kv", Arc::new(store));
        let out = server.enqueue(1, spec).recv().unwrap();
        assert!(out.report.promotions > 0);
        assert_eq!(server.metrics.counter("migration.promotions").get(), out.report.promotions);
        assert_eq!(server.metrics.counter("migration.bytes").get(), out.report.migration_bytes);
        server.shutdown();
    }
}
