//! Per-server queue + engine worker pool (Fig. 6 ②: "invocation
//! payloads … are pushed into a local queue, which are fetched by an
//! engine asynchronously").

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::Config;
use crate::porter::engine::{run_invocation, EngineConfig, InvocationOutcome};
use crate::porter::gateway::FunctionSpec;
use crate::porter::sysload::SystemLoad;
use crate::porter::tuner::OfflineTuner;

enum Job {
    Invoke { id: u64, spec: FunctionSpec, done: Sender<InvocationOutcome> },
    Stop,
}

/// One simulated server: queue, engine workers, and its own memory-load
/// accounting shared by the workers.
pub struct Server {
    pub index: usize,
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    outstanding: Arc<AtomicUsize>,
    pub sysload: Arc<SystemLoad>,
}

impl Server {
    pub fn spawn(index: usize, cfg: &Config, tuner: Arc<OfflineTuner>) -> Server {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let outstanding = Arc::new(AtomicUsize::new(0));
        let sysload = Arc::new(SystemLoad::new(&cfg.machine));
        let engine_cfg = EngineConfig::from(cfg);
        let workers = (0..cfg.porter.workers_per_server)
            .map(|w| {
                let rx = Arc::clone(&rx);
                let outstanding = Arc::clone(&outstanding);
                let sysload = Arc::clone(&sysload);
                let tuner = Arc::clone(&tuner);
                let engine_cfg = engine_cfg.clone();
                std::thread::Builder::new()
                    .name(format!("porter-s{index}w{w}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(Job::Invoke { id, spec, done }) => {
                                let outcome =
                                    run_invocation(id, &spec, &engine_cfg, &sysload, &tuner);
                                outstanding.fetch_sub(1, Ordering::Relaxed);
                                let _ = done.send(outcome);
                            }
                            Ok(Job::Stop) | Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Server { index, tx, workers, outstanding, sysload }
    }

    /// Push an invocation; returns the completion channel.
    pub fn enqueue(&self, id: u64, spec: FunctionSpec) -> Receiver<InvocationOutcome> {
        let (done_tx, done_rx) = channel();
        self.outstanding.fetch_add(1, Ordering::Relaxed);
        self.tx.send(Job::Invoke { id, spec, done: done_tx }).expect("server stopped");
        done_rx
    }

    /// Queued + running invocations (balancer signal).
    pub fn load(&self) -> usize {
        self.outstanding.load(Ordering::Relaxed)
    }

    pub fn shutdown(self) {
        for _ in &self.workers {
            let _ = self.tx.send(Job::Stop);
        }
        for w in self.workers {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::porter::gateway::FunctionSpec;
    use crate::workloads::json_ser::JsonSer;

    #[test]
    fn serves_jobs_in_parallel_workers() {
        let mut cfg = Config::default();
        cfg.porter.workers_per_server = 4;
        let tuner = Arc::new(OfflineTuner::new(&cfg));
        let server = Server::spawn(0, &cfg, tuner);
        let spec = FunctionSpec::new("json", Arc::new(JsonSer::new(50)));
        let rxs: Vec<_> = (0..8).map(|i| server.enqueue(i, spec.clone())).collect();
        for rx in rxs {
            let out = rx.recv().unwrap();
            assert_eq!(out.function, "json");
        }
        assert_eq!(server.load(), 0);
        server.shutdown();
    }
}
