//! Property-testing mini-framework (proptest substitute for the offline
//! image).
//!
//! Usage (`no_run`: doctest binaries bypass the crate's rpath wiring to
//! the xla_extension libstdc++ bundle, so they compile-check only):
//! ```no_run
//! use porter::testing::{forall, Gen};
//! forall("sorted stays sorted", 200, |g| {
//!     let mut v = g.vec_u64(0, 1000, 0..64);
//!     v.sort();
//!     assert!(v.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```
//!
//! Failures re-raise the inner panic annotated with the case seed so a
//! failing case can be replayed deterministically with
//! `PORTER_PROP_SEED=<seed>`.

use crate::util::prng::Rng;

/// Value generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case_seed: u64,
}

impl Gen {
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.rng.gen_range(hi - lo)
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.usize_in(lo, hi)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_in(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vec of uniform u64 in `[lo, hi)` with length drawn from `len`.
    pub fn vec_u64(&mut self, lo: u64, hi: u64, len: std::ops::Range<usize>) -> Vec<u64> {
        let n = self.usize_in(len.start, len.end.max(len.start + 1));
        (0..n).map(|_| self.u64_in(lo, hi)).collect()
    }

    pub fn vec_f64(&mut self, lo: f64, hi: f64, len: std::ops::Range<usize>) -> Vec<f64> {
        let n = self.usize_in(len.start, len.end.max(len.start + 1));
        (0..n).map(|_| self.f64_in(lo, hi)).collect()
    }

    /// Choose one of the provided items.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        &items[self.usize_in(0, items.len())]
    }
}

/// Run `cases` random cases of `prop`. On panic, reports the case seed.
pub fn forall(name: &str, cases: u32, prop: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    // Base seed: fixed by default for reproducible CI; override to replay
    // a specific failing case.
    let (base, replay_one) = match std::env::var("PORTER_PROP_SEED") {
        Ok(s) => (s.parse::<u64>().expect("PORTER_PROP_SEED must be u64"), true),
        Err(_) => (0x5EED_0000u64 ^ fxhash(name), false),
    };
    let n = if replay_one { 1 } else { cases };
    let mut seeder = Rng::new(base);
    for i in 0..n {
        let case_seed = if replay_one { base } else { seeder.next_u64() };
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen { rng: Rng::new(case_seed), case_seed };
            prop(&mut g);
        });
        if let Err(payload) = result {
            eprintln!(
                "property {name:?} failed on case {i}/{n} — replay with PORTER_PROP_SEED={case_seed}"
            );
            std::panic::resume_unwind(payload);
        }
    }
}

/// Tiny FNV-style string hash to derive per-property base seeds.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        use std::sync::atomic::{AtomicU32, Ordering};
        static COUNT: AtomicU32 = AtomicU32::new(0);
        forall("counts", 50, |_g| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn failing_property_panics_with_seed() {
        let r = std::panic::catch_unwind(|| {
            forall("always-fails", 10, |_g| panic!("boom"));
        });
        assert!(r.is_err());
    }

    #[test]
    fn gen_ranges_hold() {
        forall("gen-ranges", 100, |g| {
            let v = g.u64_in(10, 20);
            assert!((10..20).contains(&v));
            let f = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
            let xs = g.vec_u64(0, 5, 0..8);
            assert!(xs.len() < 8);
            assert!(xs.iter().all(|&x| x < 5));
        });
    }

    #[test]
    fn deterministic_base_seed() {
        // same property name → same sequence of case seeds
        let mut a = Rng::new(0x5EED_0000u64 ^ fxhash("p"));
        let mut b = Rng::new(0x5EED_0000u64 ^ fxhash("p"));
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
