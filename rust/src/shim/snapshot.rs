//! Sandbox state capture — what the shim knows about a finished
//! invocation's memory image.
//!
//! The paper's shim records *memory objects* so later invocations can
//! skip rediscovery; TrEnv-style warm pools go one step further and keep
//! (or snapshot) the whole execution environment. A [`SandboxImage`] is
//! the shim-level summary of that environment: the object list (site,
//! size, mmap-vs-brk) plus the per-tier residency the run peaked at.
//! The lifecycle layer (`crate::lifecycle`) stores images in warm pools
//! and demotes them into the shared CXL pool as snapshots.

use crate::shim::object::MemoryObject;

/// One entry of a captured object list — the durable subset of
/// [`MemoryObject`] (addresses are regenerated deterministically on
/// restore, so only identity + size + segment matter).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ObjectRecord {
    pub site: String,
    pub bytes: u64,
    pub via_mmap: bool,
}

/// Captured memory state of one sandbox after an invocation.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SandboxImage {
    /// Allocation-site records, in shim log (allocation) order.
    pub objects: Vec<ObjectRecord>,
    /// Bytes allocated via the brk heap (small allocations).
    pub heap_bytes: u64,
    /// Bytes allocated via the mmap segment (large allocations).
    pub mmap_bytes: u64,
    /// Peak residency per tier during the run — what keeping the
    /// sandbox warm pins in memory.
    pub dram_resident_bytes: u64,
    pub cxl_resident_bytes: u64,
}

impl SandboxImage {
    /// Capture from the shim's allocation log plus the run's per-tier
    /// peaks (from the machine report).
    pub fn capture(
        objects: &[MemoryObject],
        dram_resident_bytes: u64,
        cxl_resident_bytes: u64,
    ) -> SandboxImage {
        Self::capture_owned(objects.to_vec(), dram_resident_bytes, cxl_resident_bytes)
    }

    /// Capture by consuming the object log — no per-record `String`
    /// clones. The serving path builds an image on every invocation, so
    /// the common case must not deep-copy allocation sites.
    pub fn capture_owned(
        objects: Vec<MemoryObject>,
        dram_resident_bytes: u64,
        cxl_resident_bytes: u64,
    ) -> SandboxImage {
        let mut heap_bytes = 0u64;
        let mut mmap_bytes = 0u64;
        let records = objects
            .into_iter()
            .map(|o| {
                if o.via_mmap {
                    mmap_bytes += o.bytes;
                } else {
                    heap_bytes += o.bytes;
                }
                ObjectRecord { site: o.site, bytes: o.bytes, via_mmap: o.via_mmap }
            })
            .collect();
        SandboxImage {
            objects: records,
            heap_bytes,
            mmap_bytes,
            dram_resident_bytes,
            cxl_resident_bytes,
        }
    }

    /// Memory a warm sandbox pins (both tiers). Never zero: even an
    /// empty sandbox occupies its runtime's base footprint of one page.
    pub fn resident_bytes(&self) -> u64 {
        (self.dram_resident_bytes + self.cxl_resident_bytes).max(1)
    }

    /// Bytes that must cross a CXL link when this image is snapshotted
    /// into (or restored out of) the shared pool. CXL-resident pages are
    /// already pool-backed media in the snapshot model, so only the
    /// DRAM-resident hot set is copied.
    pub fn transfer_bytes(&self) -> u64 {
        self.dram_resident_bytes.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::shim::object::ObjectId;

    fn obj(site: &str, bytes: u64, via_mmap: bool) -> MemoryObject {
        MemoryObject { id: ObjectId(0), start: 0, bytes, site: site.into(), seq: 0, via_mmap }
    }

    #[test]
    fn capture_splits_heap_and_mmap() {
        let objs =
            [obj("a", 100, false), obj("b", 4096, true), obj("c", 50, false)];
        let img = SandboxImage::capture(&objs, 3000, 1196);
        assert_eq!(img.objects.len(), 3);
        assert_eq!(img.heap_bytes, 150);
        assert_eq!(img.mmap_bytes, 4096);
        assert_eq!(img.resident_bytes(), 4196);
        assert_eq!(img.transfer_bytes(), 3000);
    }

    #[test]
    fn empty_image_still_occupies() {
        let img = SandboxImage::capture(&[], 0, 0);
        assert_eq!(img.resident_bytes(), 1);
        assert_eq!(img.transfer_bytes(), 1);
    }

    #[test]
    fn roundtrip_equality_is_exact() {
        let objs = [obj("x", 7, false), obj("y", 1 << 20, true)];
        let a = SandboxImage::capture(&objs, 10, 20);
        let b = a.clone();
        assert_eq!(a, b);
    }
}
