//! Memory objects — the placement granularity of §3.

/// Stable identifier for a tracked allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ObjectId(pub u32);

/// A tracked allocation: what the paper's shim records per `mmap`/`brk`
/// growth — timestamp (here: allocation sequence number), size, start
/// address, and call stack (here: a site label provided by the workload,
/// playing the role of the hashed call stack).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MemoryObject {
    pub id: ObjectId,
    pub start: u64,
    pub bytes: u64,
    /// Allocation-site label (the paper hashes the call stack; workloads
    /// here pass a stable name like `"pagerank/out_contrib"`).
    pub site: String,
    /// Allocation sequence number — the shim's logical timestamp.
    pub seq: u64,
    /// Whether the allocation was served from the mmap segment (true) or
    /// by growing the brk heap (false).
    pub via_mmap: bool,
}

impl MemoryObject {
    pub fn end(&self) -> u64 {
        self.start + self.bytes
    }

    pub fn contains(&self, addr: u64) -> bool {
        addr >= self.start && addr < self.end()
    }

    /// Overlap in bytes with the half-open address range `[lo, hi)`.
    pub fn overlap(&self, lo: u64, hi: u64) -> u64 {
        let s = self.start.max(lo);
        let e = self.end().min(hi);
        e.saturating_sub(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn obj(start: u64, bytes: u64) -> MemoryObject {
        MemoryObject { id: ObjectId(0), start, bytes, site: "s".into(), seq: 0, via_mmap: true }
    }

    #[test]
    fn contains_and_end() {
        let o = obj(100, 50);
        assert!(o.contains(100));
        assert!(o.contains(149));
        assert!(!o.contains(150));
        assert!(!o.contains(99));
        assert_eq!(o.end(), 150);
    }

    #[test]
    fn overlap_cases() {
        let o = obj(100, 100); // [100, 200)
        assert_eq!(o.overlap(0, 100), 0); // disjoint below
        assert_eq!(o.overlap(200, 300), 0); // disjoint above
        assert_eq!(o.overlap(150, 250), 50); // right
        assert_eq!(o.overlap(50, 150), 50); // left
        assert_eq!(o.overlap(0, 1000), 100); // containing
        assert_eq!(o.overlap(120, 130), 10); // contained
    }
}
