//! The instrumented-process environment workloads run against.
//!
//! `Env` = intercepting allocator + event sink. Workloads allocate
//! [`TVec`]s (traced vectors) and go through `get`/`set`, which perform
//! the *real* load/store on the backing `Vec` **and** emit the logical
//! access to the sink. This keeps algorithms genuinely executing (BFS
//! really traverses, PageRank really converges) while the memory system
//! under test sees their true access streams.

use crate::shim::intercept::InterceptingAllocator;
use crate::shim::object::{MemoryObject, ObjectId};
use crate::trace::{AccessTrace, Sink, TraceRecorder};

/// Instrumented process: allocator + sink + counters.
///
/// In *recording mode* ([`Env::new_recording`]) every event additionally
/// streams into an exact [`TraceRecorder`], so the live run doubles as
/// the canonical Trace-IR capture — record once, replay everywhere —
/// at the cost of one buffered copy of the event stream.
pub struct Env<'s> {
    alloc: InterceptingAllocator,
    sink: &'s mut dyn Sink,
    recorder: Option<TraceRecorder>,
    accesses: u64,
}

impl<'s> Env<'s> {
    pub fn new(page_bytes: u64, sink: &'s mut dyn Sink) -> Env<'s> {
        Env { alloc: InterceptingAllocator::new(page_bytes), sink, recorder: None, accesses: 0 }
    }

    /// Recording mode: tee every event into an exact recorder alongside
    /// the sink. Exact (unmerged) recording is what makes the
    /// replay-identity invariant hold bit-for-bit — the replayed sink
    /// sees the same call sequence the live sink saw.
    pub fn new_recording(page_bytes: u64, sink: &'s mut dyn Sink) -> Env<'s> {
        Env {
            alloc: InterceptingAllocator::new(page_bytes),
            sink,
            recorder: Some(TraceRecorder::exact()),
            accesses: 0,
        }
    }

    /// Finish a recording-mode run and take the captured trace
    /// (`None` when the env was built with [`Env::new`]). The caller
    /// stamps `workload`/`checksum` before storing it.
    pub fn finish_recording(self) -> Option<AccessTrace> {
        let page_bytes = self.alloc.page_size();
        self.recorder.map(|r| {
            let mut t = r.finish();
            t.page_bytes = page_bytes;
            t
        })
    }

    /// Allocate a traced vector of `n` copies of `init`.
    pub fn tvec<T: Copy>(&mut self, n: usize, init: T, site: &str) -> TVec<T> {
        let bytes = (n * std::mem::size_of::<T>()).max(1) as u64;
        let obj = self.alloc.malloc(bytes, site);
        self.sink.alloc(&obj);
        if let Some(r) = &mut self.recorder {
            r.alloc(&obj);
        }
        TVec { data: vec![init; n], base: obj.start, id: obj.id }
    }

    /// Allocate a traced vector built from an iterator.
    pub fn tvec_from<T: Copy>(&mut self, data: Vec<T>, site: &str) -> TVec<T> {
        let bytes = (data.len() * std::mem::size_of::<T>()).max(1) as u64;
        let obj = self.alloc.malloc(bytes, site);
        self.sink.alloc(&obj);
        if let Some(r) = &mut self.recorder {
            r.alloc(&obj);
        }
        TVec { data, base: obj.start, id: obj.id }
    }

    /// Free a traced vector (emits the shim's munmap/free event).
    pub fn free<T>(&mut self, v: TVec<T>) {
        if let Some(obj) = self.alloc.free(v.id) {
            self.sink.free(&obj);
            if let Some(r) = &mut self.recorder {
                r.free(&obj);
            }
        }
    }

    /// Record pure compute work, in core cycles.
    #[inline]
    pub fn compute(&mut self, cycles: u64) {
        self.sink.compute(cycles);
        if let Some(r) = &mut self.recorder {
            r.compute(cycles);
        }
    }

    /// Mark a named execution phase.
    pub fn phase(&mut self, name: &str) {
        self.sink.phase(name);
        if let Some(r) = &mut self.recorder {
            r.phase(name);
        }
    }

    /// Lane annotation: subsequent events run on `lane`, after every
    /// event previously charged to a lane in `after`'s mask. Sinks
    /// without a lane model (and machines with `[lanes]` off) ignore it.
    #[inline]
    pub fn lane(&mut self, lane: u8, after: u64) {
        self.sink.lane(lane, after);
        if let Some(r) = &mut self.recorder {
            r.lane(lane, after);
        }
    }

    #[inline]
    pub(crate) fn emit(&mut self, addr: u64, bytes: u32, write: bool) {
        self.accesses += 1;
        self.sink.access(addr, bytes, write);
        if let Some(r) = &mut self.recorder {
            r.access(addr, bytes, write);
        }
    }

    /// Total traced accesses so far.
    pub fn access_count(&self) -> u64 {
        self.accesses
    }

    /// The shim's allocation log (object registry), for hint generation.
    pub fn objects(&self) -> &[MemoryObject] {
        self.alloc.log()
    }

    pub fn live_bytes(&self) -> u64 {
        self.alloc.live_bytes()
    }

    pub fn find_object(&self, addr: u64) -> Option<&MemoryObject> {
        self.alloc.find(addr)
    }
}

/// A traced vector: real data + simulated base address.
///
/// `get`/`set` emit one access per element touch. `*_untraced` variants
/// skip emission — for initialization that the paper's tooling would also
/// not see (e.g. building the input graph before the function runs) and
/// for assertions.
#[derive(Debug, Clone)]
pub struct TVec<T> {
    data: Vec<T>,
    base: u64,
    id: ObjectId,
}

impl<T: Copy> TVec<T> {
    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn id(&self) -> ObjectId {
        self.id
    }

    pub fn base(&self) -> u64 {
        self.base
    }

    #[inline]
    fn addr(&self, i: usize) -> u64 {
        self.base + (i * std::mem::size_of::<T>()) as u64
    }

    /// Traced read.
    #[inline]
    pub fn get(&self, i: usize, env: &mut Env) -> T {
        env.emit(self.addr(i), std::mem::size_of::<T>() as u32, false);
        self.data[i]
    }

    /// Traced write.
    #[inline]
    pub fn set(&mut self, i: usize, v: T, env: &mut Env) {
        env.emit(self.addr(i), std::mem::size_of::<T>() as u32, true);
        self.data[i] = v;
    }

    /// Traced read-modify-write.
    #[inline]
    pub fn update(&mut self, i: usize, env: &mut Env, f: impl FnOnce(T) -> T) {
        let addr = self.addr(i);
        let sz = std::mem::size_of::<T>() as u32;
        env.emit(addr, sz, false);
        let v = f(self.data[i]);
        env.emit(addr, sz, true);
        self.data[i] = v;
    }

    /// Untraced read (setup/verification only).
    #[inline]
    pub fn get_untraced(&self, i: usize) -> T {
        self.data[i]
    }

    /// Untraced write (setup only).
    #[inline]
    pub fn set_untraced(&mut self, i: usize, v: T) {
        self.data[i] = v;
    }

    /// Traced sequential scan of `[lo, hi)` — emits one access per
    /// element and hands each value to `f`. Dense kernels use this to
    /// keep the per-element emission on one call path.
    #[inline]
    pub fn scan(&self, lo: usize, hi: usize, env: &mut Env, mut f: impl FnMut(usize, T)) {
        let sz = std::mem::size_of::<T>() as u32;
        for i in lo..hi {
            env.emit(self.addr(i), sz, false);
            f(i, self.data[i]);
        }
    }

    /// Raw slice (untraced) for result verification.
    pub fn raw(&self) -> &[T] {
        &self.data
    }

    /// Raw mutable slice (untraced). Dense kernels (LU, GEMM) compute on
    /// the raw data and emit their memory traffic separately with
    /// [`TVec::touch_range`] at cache-line granularity — the documented
    /// granularity convention for register-blocked inner loops.
    pub fn raw_mut(&mut self) -> &mut [T] {
        &mut self.data
    }

    /// Emit one access per cache line covering elements `[lo, hi)`.
    /// Equivalent miss behaviour to per-element emission for contiguous
    /// sweeps, at 1/`line/size_of::<T>()` the event count; the folded-in
    /// L1/L2 hit cost is part of the caller's compute budget.
    pub fn touch_range(&self, lo: usize, hi: usize, write: bool, env: &mut Env) {
        const LINE: u64 = 64;
        if hi <= lo {
            return;
        }
        let start = self.addr(lo);
        let end = self.addr(hi - 1) + std::mem::size_of::<T>() as u64;
        let mut line = start & !(LINE - 1);
        while line < end {
            env.emit(line, LINE as u32, write);
            line += LINE;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::NullSink;

    #[test]
    fn tvec_reads_writes_real_data() {
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let mut v = env.tvec::<u64>(100, 0, "v");
        v.set(3, 42, &mut env);
        assert_eq!(v.get(3, &mut env), 42);
        assert_eq!(v.get_untraced(3), 42);
        drop(v);
        assert_eq!(env.access_count(), 2);
        assert_eq!(sink.accesses, 2);
        assert_eq!(sink.allocs, 1);
    }

    #[test]
    fn addresses_line_up_with_object() {
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let v = env.tvec::<u32>(100_000, 0, "big"); // 400KB → mmap
        let obj = env.objects()[0].clone();
        assert_eq!(v.base(), obj.start);
        assert!(obj.via_mmap);
        assert_eq!(obj.bytes, 400_000);
        assert_eq!(obj.site, "big");
    }

    #[test]
    fn update_emits_read_then_write() {
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let mut v = env.tvec::<u64>(4, 10, "v");
        v.update(0, &mut env, |x| x + 1);
        assert_eq!(v.get_untraced(0), 11);
        assert_eq!(sink.accesses, 2);
    }

    #[test]
    fn scan_visits_all() {
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let v = env.tvec_from((0u64..50).collect(), "v");
        let mut sum = 0;
        v.scan(10, 20, &mut env, |_, x| sum += x);
        assert_eq!(sum, (10..20).sum::<u64>());
        assert_eq!(sink.accesses, 10);
    }

    #[test]
    fn recording_env_tees_the_stream() {
        let mut sink = NullSink::default();
        let mut env = Env::new_recording(4096, &mut sink);
        let mut v = env.tvec::<u64>(64, 0, "v");
        v.set(1, 7, &mut env);
        env.compute(5);
        env.phase("p");
        let x = v.get(1, &mut env);
        env.free(v);
        let trace = env.finish_recording().expect("recording mode");
        assert_eq!(x, 7);
        // the sink saw the live stream…
        assert_eq!(sink.accesses, 2);
        assert_eq!(sink.compute_cycles, 5);
        // …and the recorder captured the identical stream
        assert_eq!(trace.n_accesses(), 2);
        assert_eq!(trace.compute_cycles(), 5);
        assert_eq!(trace.objects.len(), 1);
        assert_eq!(trace.phases, vec!["p".to_string()]);
        assert_eq!(trace.page_bytes, 4096);
        // replaying the trace reproduces the sink's view exactly
        let mut sink2 = NullSink::default();
        trace.replay(&mut sink2);
        assert_eq!(sink2.accesses, sink.accesses);
        assert_eq!(sink2.bytes, sink.bytes);
        assert_eq!(sink2.compute_cycles, sink.compute_cycles);
        assert_eq!(sink2.allocs, sink.allocs);
    }

    #[test]
    fn plain_env_records_nothing() {
        let mut sink = NullSink::default();
        let env = Env::new(4096, &mut sink);
        assert!(env.finish_recording().is_none());
    }

    #[test]
    fn free_emits_event() {
        let mut sink = NullSink::default();
        let mut env = Env::new(4096, &mut sink);
        let v = env.tvec::<u8>(200_000, 0, "v");
        assert_eq!(env.live_bytes(), 200_000);
        env.free(v);
        assert_eq!(env.live_bytes(), 0);
    }
}
