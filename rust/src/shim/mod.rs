//! The shim layer (§3.2 of the paper).
//!
//! The paper interposes on `mmap`/`brk` with `syscall_intercept` to learn
//! *memory objects* — (timestamp, size, start address, call site) — and
//! later matches DAMON's hot regions against them. Our simulated
//! processes allocate through [`intercept::InterceptingAllocator`], which
//! reproduces glibc's dispatch: requests ≥ `MMAP_THRESHOLD` go to the
//! mmap segment, smaller ones to the brk heap. `randomize_va_space` is
//! effectively disabled (the paper disables it too): addresses are
//! deterministic across runs, which is what makes profile-then-place
//! work.
//!
//! [`env::Env`] wraps the allocator + a [`crate::trace::Sink`] into the
//! instrumented-process handle workloads run against.

pub mod env;
pub mod intercept;
pub mod object;
pub mod snapshot;

pub use env::{Env, TVec};
pub use intercept::{InterceptingAllocator, MMAP_THRESHOLD};
pub use object::{MemoryObject, ObjectId};
pub use snapshot::{ObjectRecord, SandboxImage};
