//! Allocation interception — the simulated `syscall_intercept` shim.
//!
//! Reproduces the glibc malloc dispatch the paper relies on (§3.2):
//! requests of `MMAP_THRESHOLD` (128 KiB) or more are served by `mmap`
//! in the Memory Mapping Segment; smaller requests grow the heap via
//! `brk`. Every allocation is recorded as a [`MemoryObject`] with its
//! site label and sequence number. Addresses are deterministic
//! (ASLR off), so a profile run and a placement run see identical
//! object layouts.

use std::collections::BTreeMap;

use super::object::{MemoryObject, ObjectId};

/// glibc's default M_MMAP_THRESHOLD.
pub const MMAP_THRESHOLD: u64 = 128 * 1024;

/// Base of the simulated brk heap.
pub const HEAP_BASE: u64 = 0x0000_1000_0000;
/// Base of the simulated Memory Mapping Segment (grows upward here for
/// simplicity; determinism is what matters, not direction).
pub const MMAP_BASE: u64 = 0x7f00_0000_0000;

/// The interceptor: a deterministic virtual-address allocator + object
/// registry.
#[derive(Debug)]
pub struct InterceptingAllocator {
    heap_brk: u64,
    mmap_next: u64,
    next_id: u32,
    seq: u64,
    /// Live objects keyed by start address for O(log n) addr→object.
    live: BTreeMap<u64, MemoryObject>,
    /// Everything ever allocated (the shim's record log).
    log: Vec<MemoryObject>,
    page: u64,
}

impl InterceptingAllocator {
    pub fn new(page: u64) -> InterceptingAllocator {
        assert!(page.is_power_of_two());
        InterceptingAllocator {
            heap_brk: HEAP_BASE,
            mmap_next: MMAP_BASE,
            next_id: 0,
            seq: 0,
            live: BTreeMap::new(),
            log: Vec::new(),
            page,
        }
    }

    /// Allocate `bytes` with glibc-style dispatch; returns the object.
    pub fn malloc(&mut self, bytes: u64, site: &str) -> MemoryObject {
        assert!(bytes > 0, "malloc(0)");
        let via_mmap = bytes >= MMAP_THRESHOLD;
        let start = if via_mmap {
            // mmap allocations are page-aligned and page-granular
            let start = self.mmap_next;
            self.mmap_next += round_up(bytes, self.page);
            start
        } else {
            // brk: bump the heap, 16-byte aligned like malloc chunks
            let start = round_up(self.heap_brk, 16);
            self.heap_brk = start + bytes;
            start
        };
        let obj = MemoryObject {
            id: ObjectId(self.next_id),
            start,
            bytes,
            site: site.to_string(),
            seq: self.seq,
            via_mmap,
        };
        self.next_id += 1;
        self.seq += 1;
        self.live.insert(start, obj.clone());
        self.log.push(obj.clone());
        obj
    }

    /// Release an object (munmap / heap free). The address range is not
    /// recycled — determinism and post-mortem attribution matter more
    /// than virtual-address frugality in a 47-bit space.
    pub fn free(&mut self, id: ObjectId) -> Option<MemoryObject> {
        let key = self.live.iter().find(|(_, o)| o.id == id).map(|(k, _)| *k)?;
        self.live.remove(&key)
    }

    /// Object containing `addr`, if any is live.
    pub fn find(&self, addr: u64) -> Option<&MemoryObject> {
        self.live
            .range(..=addr)
            .next_back()
            .map(|(_, o)| o)
            .filter(|o| o.contains(addr))
    }

    /// All allocations ever made, in sequence order.
    pub fn log(&self) -> &[MemoryObject] {
        &self.log
    }

    pub fn live_objects(&self) -> impl Iterator<Item = &MemoryObject> {
        self.live.values()
    }

    pub fn live_bytes(&self) -> u64 {
        self.live.values().map(|o| o.bytes).sum()
    }

    pub fn page_size(&self) -> u64 {
        self.page
    }
}

fn round_up(v: u64, to: u64) -> u64 {
    (v + to - 1) & !(to - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dispatch_by_threshold() {
        let mut a = InterceptingAllocator::new(4096);
        let small = a.malloc(1024, "small");
        let big = a.malloc(MMAP_THRESHOLD, "big");
        assert!(!small.via_mmap);
        assert!(big.via_mmap);
        assert!(small.start >= HEAP_BASE && small.start < MMAP_BASE);
        assert!(big.start >= MMAP_BASE);
        assert_eq!(big.start % 4096, 0);
    }

    #[test]
    fn deterministic_addresses() {
        let run = || {
            let mut a = InterceptingAllocator::new(4096);
            let x = a.malloc(200_000, "x").start;
            let y = a.malloc(50, "y").start;
            let z = a.malloc(300_000, "z").start;
            (x, y, z)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn mmap_regions_do_not_overlap() {
        let mut a = InterceptingAllocator::new(4096);
        let o1 = a.malloc(130_000, "a");
        let o2 = a.malloc(130_000, "b");
        assert!(o1.end() <= o2.start);
    }

    #[test]
    fn find_by_address() {
        let mut a = InterceptingAllocator::new(4096);
        let o = a.malloc(200_000, "obj");
        assert_eq!(a.find(o.start).unwrap().id, o.id);
        assert_eq!(a.find(o.start + o.bytes - 1).unwrap().id, o.id);
        assert!(a.find(o.end() + 4096 * 100).is_none());
        // address below every object
        assert!(a.find(0).is_none());
    }

    #[test]
    fn free_removes_from_live_keeps_log() {
        let mut a = InterceptingAllocator::new(4096);
        let o = a.malloc(200_000, "obj");
        assert_eq!(a.live_bytes(), 200_000);
        let freed = a.free(o.id).unwrap();
        assert_eq!(freed.id, o.id);
        assert_eq!(a.live_bytes(), 0);
        assert!(a.find(o.start).is_none());
        assert_eq!(a.log().len(), 1);
        assert!(a.free(o.id).is_none());
    }

    #[test]
    fn seq_increases() {
        let mut a = InterceptingAllocator::new(4096);
        let s1 = a.malloc(10, "a").seq;
        let s2 = a.malloc(10, "b").seq;
        assert!(s2 > s1);
    }
}
