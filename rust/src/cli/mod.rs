//! Minimal CLI argument parser (clap substitute): subcommand + `--key
//! value` / `--flag` options, with typed accessors and usage errors.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse: first non-flag token is the subcommand, later non-flag
    /// tokens are positional; `--key value` pairs and bare `--flag`s.
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(name) = tok.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(tok.clone());
            } else {
                out.positional.push(tok.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| format!("--{name} expects a number, got {v:?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("run pagerank --tier cxl --iters 5 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.positional, vec!["pagerank"]);
        assert_eq!(a.opt("tier"), Some("cxl"));
        assert_eq!(a.opt_usize("iters", 1).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("serve --servers=4 --budget=0.5");
        assert_eq!(a.opt_usize("servers", 1).unwrap(), 4);
        assert_eq!(a.opt_f64("budget", 0.0).unwrap(), 0.5);
    }

    #[test]
    fn trailing_flag_not_eating_subcommand() {
        let a = parse("--show config");
        // --show takes "config" as its value in `--key value` form
        assert_eq!(a.opt("show"), Some("config"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --n abc");
        assert!(a.opt_usize("n", 0).is_err());
        assert!(a.opt_f64("n", 0.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.opt_or("tier", "dram"), "dram");
        assert_eq!(a.opt_usize("n", 7).unwrap(), 7);
    }
}
