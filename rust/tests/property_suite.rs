//! Property-based tests over the coordinator's core invariants:
//! routing/occupancy accounting, placement, migration, cache, DAMON
//! region bookkeeping, trace replay, and the JSON/TOML codecs.

use porter::config::{Config, MachineConfig};
use porter::mem::page::PageNo;
use porter::mem::tier::TierKind;
use porter::mem::tiered::{FixedPlacer, Migration, TieredMemory};
use porter::porter::balancer::{LeastLoaded, Loaded};
use porter::porter::sysload::SystemLoad;
use porter::shim::intercept::{InterceptingAllocator, MMAP_THRESHOLD};
use porter::shim::object::MemoryObject;
use porter::sim::Cache;
use porter::testing::{forall, Gen};
use porter::trace::{NullSink, TraceRecorder};
use porter::util::json::Json;

/// Allocator: objects never overlap, addresses deterministic, dispatch
/// follows MMAP_THRESHOLD.
#[test]
fn prop_allocator_objects_never_overlap() {
    forall("allocator-no-overlap", 60, |g: &mut Gen| {
        let mut a = InterceptingAllocator::new(4096);
        let mut objs: Vec<MemoryObject> = Vec::new();
        for i in 0..g.usize_in(1, 40) {
            let sz = g.u64_in(1, 4 * MMAP_THRESHOLD);
            let o = a.malloc(sz, &format!("s{i}"));
            assert_eq!(o.via_mmap, sz >= MMAP_THRESHOLD);
            for prev in &objs {
                assert!(
                    o.start >= prev.end() || o.end() <= prev.start,
                    "overlap: {o:?} vs {prev:?}"
                );
            }
            objs.push(o);
        }
    });
}

/// Tier accounting: used bytes equal page_bytes × mapped pages after any
/// sequence of map/migrate/unmap operations.
#[test]
fn prop_tier_accounting_balances() {
    forall("tier-accounting", 40, |g: &mut Gen| {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = g.u64_in(4, 64) * cfg.page_bytes;
        cfg.cxl_bytes = 1 << 30;
        let mut mem = TieredMemory::new(&cfg);
        let mut next = porter::shim::intercept::MMAP_BASE;
        let mut objs = Vec::new();
        for i in 0..g.usize_in(1, 12) {
            let pages = g.u64_in(1, 20);
            let o = MemoryObject {
                id: porter::shim::object::ObjectId(i as u32),
                start: next,
                bytes: pages * cfg.page_bytes,
                site: format!("o{i}"),
                seq: i as u64,
                via_mmap: true,
            };
            next += pages * cfg.page_bytes;
            let kind = if g.bool() { TierKind::Dram } else { TierKind::Cxl };
            mem.map_object(&o, &mut FixedPlacer { kind });
            objs.push(o);
        }
        // random migrations
        let pages: Vec<PageNo> = mem.pages.iter_mapped().map(|(p, _)| p).collect();
        for _ in 0..g.usize_in(0, 30) {
            let p = *g.pick(&pages);
            let cur = mem.pages.get(p).tier().unwrap();
            mem.migrate(Migration { page: p, from: cur, to: cur.other() });
        }
        // invariant: per-tier used == page_bytes × pages mapped there
        for kind in TierKind::ALL {
            let mapped = mem
                .pages
                .iter_mapped()
                .filter(|(_, m)| m.tier() == Some(kind))
                .count() as u64;
            assert_eq!(mem.used(kind), mapped * cfg.page_bytes, "{kind:?} accounting drifted");
        }
        // unmap everything → zero
        for o in &objs {
            mem.unmap_object(o, |_| false);
        }
        assert_eq!(mem.used(TierKind::Dram) + mem.used(TierKind::Cxl), 0);
    });
}

/// Migration accounting: across arbitrary (often invalid) migrate
/// sequences, per-tier occupancy always equals page_bytes × pages mapped
/// there, promotions/demotions count exactly the successful CXL→DRAM /
/// DRAM→CXL moves (symmetric), and every rejected call leaves the whole
/// state — occupancy, free bytes, counters, page table — untouched.
#[test]
fn prop_migrate_accounting_invariant() {
    forall("migrate-accounting", 60, |g: &mut Gen| {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = g.u64_in(2, 24) * cfg.page_bytes;
        cfg.cxl_bytes = g.u64_in(8, 48) * cfg.page_bytes;
        let mut mem = TieredMemory::new(&cfg);
        let pages = g.u64_in(1, 30);
        let o = MemoryObject {
            id: porter::shim::object::ObjectId(0),
            start: porter::shim::intercept::MMAP_BASE,
            bytes: pages * cfg.page_bytes,
            site: "o".into(),
            seq: 0,
            via_mmap: true,
        };
        let kind = if g.bool() { TierKind::Dram } else { TierKind::Cxl };
        mem.map_object(&o, &mut FixedPlacer { kind });

        let first = mem.pages.page_of(o.start);
        let mut expected_promotions = 0u64;
        let mut expected_demotions = 0u64;
        for _ in 0..g.usize_in(0, 80) {
            // random page (sometimes unmapped), random from/to
            // (sometimes equal, sometimes wrong)
            let p = PageNo { index: first.index + g.u64_in(0, pages + 6) as u32, ..first };
            let from = if g.bool() { TierKind::Dram } else { TierKind::Cxl };
            let to = if g.bool() { from } else { from.other() };
            let before = (
                mem.used(TierKind::Dram),
                mem.used(TierKind::Cxl),
                mem.promotions,
                mem.demotions,
                mem.pages.mapped_count(),
            );
            let valid_page = mem.pages.get(p).tier() == Some(from);
            let ok = mem.migrate(Migration { page: p, from, to });
            if ok {
                assert_ne!(from, to, "same-tier moves must be rejected");
                assert!(valid_page, "accepted move of a page not mapped in `from`");
                match to {
                    TierKind::Dram => expected_promotions += 1,
                    TierKind::Cxl => expected_demotions += 1,
                }
            } else {
                let after = (
                    mem.used(TierKind::Dram),
                    mem.used(TierKind::Cxl),
                    mem.promotions,
                    mem.demotions,
                    mem.pages.mapped_count(),
                );
                assert_eq!(after, before, "rejected migration mutated state");
            }
            // occupancy invariant after every call
            for k in TierKind::ALL {
                let mapped = mem
                    .pages
                    .iter_mapped()
                    .filter(|(_, m)| m.tier() == Some(k))
                    .count() as u64;
                assert_eq!(mem.used(k), mapped * cfg.page_bytes, "{k:?} occupancy drifted");
                assert!(mem.used(k) <= mem.tier(k).params.capacity, "{k:?} over capacity");
            }
        }
        assert_eq!(mem.promotions, expected_promotions, "promotions miscounted");
        assert_eq!(mem.demotions, expected_demotions, "demotions miscounted");
    });
}

/// Cache: hits+misses == line-accesses; a repeat pass over a small
/// working set hits; capacity is never exceeded.
#[test]
fn prop_cache_conservation() {
    forall("cache-conservation", 40, |g: &mut Gen| {
        let ways = g.u64_in(1, 16) as u32;
        let capacity = g.u64_in(4, 256) * 64 * ways as u64;
        let mut c = Cache::new(capacity, 64, ways);
        let lines = g.vec_u64(0, 1 << 20, 1..400);
        for &l in &lines {
            c.access_line(l);
        }
        assert_eq!(c.hits + c.misses, lines.len() as u64);
        // unique lines bounded below by misses? No: evictions re-miss.
        let unique: std::collections::HashSet<_> = lines.iter().collect();
        assert!(c.misses >= unique.len() as u64 * 0 + 1);
        assert!(c.misses <= lines.len() as u64);
        // tiny working set fully cached on second pass
        let mut c2 = Cache::new(capacity, 64, ways);
        let small: Vec<u64> = (0..(capacity / 64 / 2).max(1)).collect();
        for &l in &small {
            c2.access_line(l);
        }
        c2.reset_stats();
        for &l in &small {
            c2.access_line(l);
        }
        assert_eq!(c2.misses, 0, "resident set must not miss (cap {capacity}, ways {ways})");
    });
}

struct FixedLoad(usize);

impl Loaded for FixedLoad {
    fn load(&self) -> usize {
        self.0
    }
}

/// Balancer: on an equally loaded pool every server receives exactly the
/// same share (true round-robin), whatever the pool size or load level —
/// including a 1-server pool, which must never panic.
#[test]
fn prop_balancer_roundrobin_fair_on_equal_load() {
    forall("balancer-fairness", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 9);
        let load = g.usize_in(0, 6);
        let servers: Vec<FixedLoad> = (0..n).map(|_| FixedLoad(load)).collect();
        let lb = LeastLoaded::default();
        let rounds = g.usize_in(1, 6);
        let mut counts = vec![0usize; n];
        for _ in 0..rounds * n {
            counts[lb.pick(&servers)] += 1;
        }
        assert!(
            counts.iter().all(|&c| c == rounds),
            "unfair rotation over {n} servers: {counts:?}"
        );
    });
}

/// Balancer: with mixed static loads, all traffic goes to the
/// minimum-load subset, split within ±0 across full rotations (the
/// pre-fix cursor skewed tied subsets).
#[test]
fn prop_balancer_tied_subset_gets_equal_share() {
    forall("balancer-tied-subset", 60, |g: &mut Gen| {
        let n = g.usize_in(2, 9);
        let min_load = g.usize_in(0, 3);
        // at least one server at min_load, the rest at min or above
        let loads: Vec<usize> = (0..n)
            .map(|i| if i == 0 { min_load } else { min_load + g.usize_in(0, 4) })
            .collect();
        let servers: Vec<FixedLoad> = loads.iter().map(|&l| FixedLoad(l)).collect();
        let tied: Vec<usize> =
            (0..n).filter(|&i| loads[i] == min_load).collect();
        let lb = LeastLoaded::default();
        let rounds = g.usize_in(1, 5);
        let mut counts = vec![0usize; n];
        for _ in 0..rounds * tied.len() {
            counts[lb.pick(&servers)] += 1;
        }
        for i in 0..n {
            let expect = if tied.contains(&i) { rounds } else { 0 };
            assert_eq!(
                counts[i], expect,
                "server {i} (load {}) got {counts:?}, tied set {tied:?}",
                loads[i]
            );
        }
    });
}

/// SystemLoad: grants never exceed capacity under arbitrary interleaved
/// reserve/release patterns.
#[test]
fn prop_sysload_never_oversubscribes() {
    forall("sysload-bounds", 40, |g: &mut Gen| {
        let mut cfg = MachineConfig::default();
        cfg.dram_bytes = g.u64_in(1_000, 100_000);
        cfg.cxl_bytes = g.u64_in(10_000, 1_000_000);
        let load = SystemLoad::new(&cfg);
        let mut live = Vec::new();
        for _ in 0..g.usize_in(1, 50) {
            if g.bool() || live.is_empty() {
                let fp = g.u64_in(1, cfg.dram_bytes * 2);
                let r = load.reserve(fp, fp);
                assert!(r.dram + r.cxl <= fp);
                live.push(r);
            } else {
                let i = g.usize_in(0, live.len());
                live.swap_remove(i);
            }
            assert!(load.occupancy(TierKind::Dram) <= 1.0 + 1e-9);
            assert!(load.occupancy(TierKind::Cxl) <= 1.0 + 1e-9);
        }
        drop(live);
        assert_eq!(load.free(TierKind::Dram), cfg.dram_bytes);
    });
}

/// Trace record/replay: replaying a recording into a NullSink reproduces
/// the original event counts exactly, including relocation.
#[test]
fn prop_trace_replay_faithful() {
    forall("trace-replay", 40, |g: &mut Gen| {
        let mut rec = TraceRecorder::new();
        let mut env = porter::shim::Env::new(4096, &mut rec);
        let n = g.usize_in(1, 2000);
        let v = env.tvec::<u64>(40_000, 0, "buf");
        let mut reads = 0u64;
        let mut writes = 0u64;
        for _ in 0..n {
            if g.bool() {
                std::hint::black_box(v.get(g.usize_in(0, 40_000), &mut env));
                reads += 1;
            } else {
                // writes require a &mut; emit through update
                std::hint::black_box(g.usize_in(0, 40_000));
                writes += 1;
                env.compute(3);
            }
        }
        drop(env);
        let trace = rec.finish();
        let offset = g.u64_in(0, 1 << 20) * 4096;
        let mut sink = NullSink::default();
        trace.replay_range_relocated(&mut sink, 0, trace.len(), offset);
        assert_eq!(sink.accesses, reads);
        assert_eq!(sink.compute_cycles, writes * 3);
        assert_eq!(sink.allocs, 1);
    });
}

/// Trace-IR replay identity: for every workload in the registry, a live
/// run recorded through the shim reproduces, on replay into an
/// identically configured machine, the exact same `RunReport` (every
/// field, f64s included — the replay performs the same clock arithmetic
/// in the same order) and the stored checksum equals the live result.
#[test]
fn prop_replay_identity_across_registry() {
    use porter::config::MachineConfig;
    use porter::sim::Machine;
    use porter::workloads::registry::{build, Scale, NAMES};
    let cfg = MachineConfig::default();
    for name in NAMES {
        let w = build(name, Scale::Small).unwrap();
        // live run on a CXL machine, recording as it executes
        let mut live = Machine::all_in(&cfg, TierKind::Cxl);
        let mut env = porter::shim::Env::new_recording(cfg.page_bytes, &mut live);
        let checksum = w.run(&mut env);
        let mut trace = env.finish_recording().expect("recording env");
        trace.checksum = checksum;
        let live_report = live.report();
        assert_eq!(trace.checksum, checksum, "{name}: stored checksum");
        // replay into a fresh identical machine: field-for-field equal
        let mut replayed = Machine::all_in(&cfg, TierKind::Cxl);
        replayed.replay(&trace);
        assert_eq!(replayed.report(), live_report, "{name}: replay-identity (CXL)");
        // and replays are deterministic across machine configurations
        let mut a = Machine::all_in(&cfg, TierKind::Dram);
        a.replay(&trace);
        let mut b = Machine::all_in(&cfg, TierKind::Dram);
        b.replay(&trace);
        assert_eq!(a.report(), b.report(), "{name}: replay determinism (DRAM)");
        // serialization round-trip preserves the stream exactly (a
        // bounded prefix — full random-stream coverage lives in
        // prop_trace_ir_delta_roundtrip; debug-mode JSON of multi-
        // million-event traces would dominate the test's runtime)
        let slice = trace.truncated(200_000);
        let back = porter::trace::AccessTrace::from_json(&slice.to_json())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(back, slice, "{name}: JSON round-trip");
    }
}

/// Trace-IR delta encoding: arbitrary generated event streams (all six
/// event kinds, random addresses/sizes/cycles) survive the JSON
/// round-trip event-for-event.
#[test]
fn prop_trace_ir_delta_roundtrip() {
    use porter::shim::object::ObjectId;
    use porter::trace::AccessTrace;
    forall("trace-ir-roundtrip", 80, |g: &mut Gen| {
        let mut t = AccessTrace {
            workload: format!("w{}", g.u64_in(0, 1000)),
            page_bytes: 1 << g.usize_in(9, 16),
            checksum: g.u64_in(0, u64::MAX - 1),
            ..Default::default()
        };
        let mut n_objects = 0u32;
        for _ in 0..g.usize_in(1, 200) {
            match g.usize_in(0, 6) {
                0 => {
                    // addresses from both segments, arbitrary order —
                    // deltas go negative as well as positive
                    let base = if g.bool() {
                        porter::shim::intercept::HEAP_BASE
                    } else {
                        porter::shim::intercept::MMAP_BASE
                    };
                    let addr = base + g.u64_in(0, 1 << 40);
                    t.push_access(addr, g.u64_in(1, 1 << 20) as u32, g.bool());
                }
                1 => t.push_compute(g.u64_in(0, 1 << 40)),
                2 => {
                    let obj = MemoryObject {
                        id: ObjectId(n_objects),
                        start: porter::shim::intercept::MMAP_BASE + g.u64_in(0, 1 << 40),
                        bytes: g.u64_in(1, 1 << 30),
                        site: format!("site-{n_objects}-\"quoted\""),
                        seq: n_objects as u64,
                        via_mmap: g.bool(),
                    };
                    n_objects += 1;
                    t.push_alloc(&obj);
                }
                3 => {
                    if n_objects > 0 {
                        let id = ObjectId(g.usize_in(0, n_objects as usize) as u32);
                        let obj = t.objects[id.0 as usize].clone();
                        t.push_free(&obj);
                    }
                }
                4 => t.push_phase(&format!("phase{}", g.usize_in(0, 5))),
                _ => t.push_tick(),
            }
        }
        let compact = AccessTrace::from_json(&t.to_json()).unwrap();
        assert_eq!(compact, t, "delta round-trip drifted");
    });
}

/// JSON codec: round-trips arbitrary nested values.
#[test]
fn prop_json_roundtrip() {
    fn gen_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 4) } else { g.usize_in(0, 6) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool()),
            2 => Json::Num((g.f64_in(-1e9, 1e9) * 100.0).round() / 100.0),
            3 => Json::str(format!("s{}-\"quoted\"\n", g.u64_in(0, 1000))),
            4 => Json::Num(g.u64_in(0, 1 << 50) as f64),
            5 => Json::arr((0..g.usize_in(0, 4)).map(|_| gen_json(g, depth - 1))),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), gen_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json-roundtrip", 120, |g: &mut Gen| {
        let v = gen_json(g, 3);
        let compact = Json::parse(&v.to_string_compact()).unwrap();
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(compact, v);
        assert_eq!(pretty, v);
    });
}

/// Config TOML: any generated config round-trips through render+parse
/// equivalently for the keys we emit.
#[test]
fn prop_config_overrides_apply() {
    forall("config-overrides", 60, |g: &mut Gen| {
        let dram_gb = g.u64_in(1, 512);
        let servers = g.usize_in(1, 16);
        let frac = (g.f64_in(0.0, 1.0) * 100.0).round() / 100.0;
        let text = format!(
            "[machine]\ndram = \"{dram_gb}GB\"\n\n[porter]\nservers = {servers}\ndram_budget_frac = {frac:?}\n"
        );
        let cfg = Config::from_toml_str(&text).unwrap();
        assert_eq!(cfg.machine.dram_bytes, dram_gb * (1 << 30));
        assert_eq!(cfg.porter.servers, servers);
        assert!((cfg.porter.dram_budget_frac - frac).abs() < 1e-12);
    });
}

/// Page map: address→page→address round-trip for arbitrary addresses in
/// both segments.
#[test]
fn prop_pagemap_roundtrip() {
    forall("pagemap-roundtrip", 100, |g: &mut Gen| {
        let page = 1u64 << g.usize_in(9, 16);
        let pm = porter::mem::page::PageMap::new(page);
        let addr = if g.bool() {
            porter::shim::intercept::HEAP_BASE + g.u64_in(0, 1 << 30)
        } else {
            porter::shim::intercept::MMAP_BASE + g.u64_in(0, 1 << 34)
        };
        let p = pm.page_of(addr);
        let start = pm.addr_of(p);
        assert!(start <= addr && addr < start + page, "{addr:#x} not in page [{start:#x},+{page})");
    });
}

/// Warm pool: whatever sequence of insert/lookup/advance a random
/// schedule produces, the pool never exceeds its byte budget and its
/// used-bytes ledger equals the sum of the live sandboxes exactly.
#[test]
fn prop_warm_pool_never_exceeds_budget() {
    use porter::lifecycle::{policy_from_config, Sandbox, WarmPool};
    use porter::shim::SandboxImage;
    forall("warm-pool-budget", 60, |g: &mut Gen| {
        let lc = porter::config::LifecycleConfig {
            policy: ["ttl", "lru", "histogram"][g.usize_in(0, 3)].to_string(),
            ttl_ns: g.u64_in(10, 10_000),
            ..Default::default()
        };
        let budget = g.u64_in(0, 4096);
        let mut pool = WarmPool::new(budget, policy_from_config(&lc));
        let mut t = 0u64;
        for i in 0..g.usize_in(1, 60) {
            t += g.u64_in(0, 500);
            let f = format!("f{}", g.usize_in(0, 6));
            match g.usize_in(0, 3) {
                0 => {
                    let image = SandboxImage {
                        dram_resident_bytes: g.u64_in(0, 1500),
                        cxl_resident_bytes: g.u64_in(0, 1500),
                        ..SandboxImage::default()
                    };
                    let evicted = pool.insert(Sandbox::new(&f, image, t));
                    for sb in &evicted {
                        assert!(
                            !pool.contains(&sb.function, t) || sb.function == f,
                            "case {i}: evicted sandbox still live"
                        );
                    }
                }
                1 => {
                    pool.note_invocation(&f, t);
                    pool.lookup(&f, t);
                }
                _ => {
                    pool.advance(t);
                }
            }
            assert!(
                pool.used_bytes() <= pool.budget_bytes(),
                "case {i}: used {} > budget {}",
                pool.used_bytes(),
                pool.budget_bytes()
            );
            let live_sum: u64 = pool.sandboxes().iter().map(|s| s.bytes()).sum();
            assert_eq!(pool.used_bytes(), live_sum, "case {i}: ledger drifted");
        }
    });
}

/// Snapshot store: snapshot→restore round-trips preserve the sandbox's
/// object list and per-tier occupancy accounting exactly, and no pool
/// lease survives eviction (the pool returns to its baseline occupancy
/// once every snapshot is gone).
#[test]
fn prop_snapshot_roundtrip_and_no_leaked_leases() {
    use porter::cluster::pool::CxlPool;
    use porter::lifecycle::{Sandbox, SnapshotStore};
    use porter::shim::{ObjectRecord, SandboxImage};
    forall("snapshot-roundtrip", 60, |g: &mut Gen| {
        let pool_cap = g.u64_in(10_000, 100_000);
        let mut pool = CxlPool::new(pool_cap, 64.0, 30.0, 2, 1_000_000);
        let store_cap = g.u64_in(1_000, pool_cap);
        let mut store = SnapshotStore::new(store_cap, 1, g.u64_in(0, 10_000));
        let mut t = 0u64;
        let mut originals: Vec<(String, SandboxImage)> = Vec::new();
        for i in 0..g.usize_in(1, 20) {
            t += g.u64_in(1, 1_000);
            let f = format!("f{i}");
            let objects = (0..g.usize_in(0, 8))
                .map(|j| ObjectRecord {
                    site: format!("{f}/site{j}"),
                    bytes: g.u64_in(1, 10_000),
                    via_mmap: g.bool(),
                })
                .collect::<Vec<_>>();
            let image = SandboxImage {
                heap_bytes: objects.iter().filter(|o| !o.via_mmap).map(|o| o.bytes).sum(),
                mmap_bytes: objects.iter().filter(|o| o.via_mmap).map(|o| o.bytes).sum(),
                objects,
                dram_resident_bytes: g.u64_in(1, 3_000),
                cxl_resident_bytes: g.u64_in(0, 3_000),
            };
            let mut sb = Sandbox::new(&f, image.clone(), t);
            sb.uses = g.u64_in(1, 5);
            if store.admit(&sb, t, g.usize_in(0, 2), &mut pool).admitted() {
                originals.push((f, image));
            }
            // the store never leases beyond its own budget
            assert!(store.leased_bytes() <= store_cap);
        }
        // restore round-trip: every still-resident snapshot's image is
        // bit-identical to what was admitted
        let mut restored = 0;
        for (f, original) in &originals {
            if let Some(img) = store.image(f) {
                assert_eq!(img, original, "{f}: image drifted through snapshot/restore");
                t += 1;
                let (_latency, bytes) =
                    store.restore(f, t, 0, &mut pool, 30.0, 1.0).expect("resident snapshot");
                assert_eq!(bytes, original.transfer_bytes());
                restored += 1;
            }
        }
        assert!(originals.is_empty() || restored > 0 || store.metrics.evicted > 0);
        // evict everything: all leases must return to the pool
        t += 1;
        store.release_all(t, &mut pool);
        assert_eq!(store.leased_bytes(), 0);
        pool.advance(t);
        assert_eq!(
            pool.occupancy(),
            0.0,
            "snapshot leases leaked pool capacity after eviction"
        );
    });
}

/// Synthetic demand curve over the default ladder: random footprint,
/// random raw walls (the constructor clamps them monotone).
fn gen_curve(g: &mut Gen, name: &str) -> std::sync::Arc<porter::placement::DemandCurve> {
    use porter::placement::provision::CurvePoint;
    let page = 4096u64;
    let footprint = g.u64_in(1, 4096) * page;
    let ladder = Config::default().provision.ladder;
    let base_wall = g.f64_in(1e4, 1e7);
    let points = ladder
        .iter()
        .map(|&ratio| CurvePoint {
            ratio,
            dram_bytes: if ratio <= 0.0 {
                0
            } else {
                ((footprint as f64 * ratio).ceil() as u64).next_multiple_of(page)
            },
            // raw walls wander freely; DemandCurve::new enforces the
            // monotone non-increasing invariant
            wall_ns: base_wall * g.f64_in(0.1, 1.0),
        })
        .collect();
    std::sync::Arc::new(porter::placement::DemandCurve::new(name, footprint, page, points))
}

/// Demand-curve interpolation is monotone non-increasing in DRAM, stays
/// inside the endpoint walls, and `bytes_for_target` inverts it.
#[test]
fn prop_demand_curve_interpolation_monotone() {
    forall("provision-curve-monotone", 80, |g: &mut Gen| {
        let c = gen_curve(g, "f");
        let top = c.points.last().unwrap().dram_bytes;
        let mut prev_wall = f64::INFINITY;
        let mut queries: Vec<u64> = (0..32).map(|_| g.u64_in(0, top + 2 * 4096)).collect();
        queries.sort_unstable();
        for q in queries {
            let w = c.wall_at(q);
            assert!(w <= prev_wall + 1e-9, "wall_at must be non-increasing");
            assert!(w >= c.points.last().unwrap().wall_ns - 1e-9);
            assert!(w <= c.points[0].wall_ns + 1e-9);
            prev_wall = w;
        }
        // bytes_for_target inverts interpolation (up to page rounding)
        let target = g.f64_in(c.points.last().unwrap().wall_ns, c.points[0].wall_ns + 1.0);
        if let Some(need) = c.bytes_for_target(target) {
            assert!(c.wall_at(need) <= target + 1e-9);
        } else {
            assert!(c.points.last().unwrap().wall_ns > target);
        }
    });
}

/// The budget allocator never over-commits the node's DRAM, with or
/// without floors and the uniform fallback.
#[test]
fn prop_provision_allocator_never_overcommits() {
    use porter::placement::provision::{BudgetAllocator, FunctionDemand};
    forall("provision-no-overcommit", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 6);
        let demands: Vec<FunctionDemand> = (0..n)
            .map(|i| {
                let mut d = FunctionDemand::new(gen_curve(g, &format!("f{i}")));
                if g.bool() {
                    d.floor_bytes = Some(g.u64_in(0, d.curve.footprint + 4096));
                }
                if g.bool() {
                    d.weight = g.f64_in(0.1, 8.0);
                }
                d
            })
            .collect();
        let capacity = g.u64_in(0, demands.iter().map(|d| d.curve.footprint).sum::<u64>() + 1);
        let alloc = BudgetAllocator {
            min_gain_frac: g.f64_in(0.0, 0.2),
            uniform_fallback: g.bool(),
        }
        .allocate(capacity, &demands);
        assert!(
            alloc.used_bytes <= capacity,
            "over-committed: used {} of {capacity}",
            alloc.used_bytes
        );
        let sum: u64 = alloc.budgets.iter().map(|b| b.dram_bytes).sum();
        assert_eq!(sum, alloc.used_bytes, "used_bytes must equal the budget sum");
        for b in &alloc.budgets {
            assert!(b.frac <= 1.0 + 1e-9);
        }
    });
}

/// More DRAM never shrinks any function's budget (the greedy descent is
/// a capacity-independent upgrade sequence; capacity only sets the
/// prefix length). Tested floor-free and fallback-free: SLO floors
/// deliberately trade monotonicity for floor satisfaction, and the
/// uniform fallback switches arms.
#[test]
fn prop_provision_allocator_monotone_in_capacity() {
    use porter::placement::provision::{BudgetAllocator, FunctionDemand};
    forall("provision-monotone-capacity", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 5);
        let demands: Vec<FunctionDemand> =
            (0..n).map(|i| FunctionDemand::new(gen_curve(g, &format!("f{i}")))).collect();
        let total: u64 = demands.iter().map(|d| d.curve.footprint).sum();
        let c1 = g.u64_in(0, total + 1);
        let c2 = c1 + g.u64_in(0, total + 1);
        let alloc = BudgetAllocator { min_gain_frac: g.f64_in(0.0, 0.2), uniform_fallback: false };
        let a = alloc.allocate(c1, &demands);
        let b = alloc.allocate(c2, &demands);
        for (x, y) in a.budgets.iter().zip(&b.budgets) {
            assert!(
                y.dram_bytes >= x.dram_bytes,
                "capacity {c1}->{c2} shrank {} from {} to {}",
                x.function,
                x.dram_bytes,
                y.dram_bytes
            );
        }
        assert!(b.predicted_wall_ns <= a.predicted_wall_ns + 1e-6);
    });
}

/// With the uniform fallback on (the production configuration), the
/// allocation never predicts worse than uniform provisioning at equal
/// DRAM, and the total predicted wall is monotone in capacity.
#[test]
fn prop_provision_beats_or_matches_uniform() {
    use porter::placement::provision::{BudgetAllocator, FunctionDemand};
    forall("provision-vs-uniform", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 5);
        let demands: Vec<FunctionDemand> =
            (0..n).map(|i| FunctionDemand::new(gen_curve(g, &format!("f{i}")))).collect();
        let total: u64 = demands.iter().map(|d| d.curve.footprint).sum();
        let alloc = BudgetAllocator { min_gain_frac: g.f64_in(0.0, 0.2), uniform_fallback: true };
        let c1 = g.u64_in(0, total + 1);
        let a = alloc.allocate(c1, &demands);
        assert!(
            a.predicted_wall_ns <= a.uniform_wall_ns * (1.0 + 1e-12),
            "optimized {} must not lose to uniform {}",
            a.predicted_wall_ns,
            a.uniform_wall_ns
        );
        let b = alloc.allocate(c1 + g.u64_in(0, total + 1), &demands);
        assert!(b.predicted_wall_ns <= a.predicted_wall_ns + 1e-6);
        // savings are the uniform arm's spend minus ours, never negative
        assert!(a.dram_saved_bytes() <= a.uniform_used_bytes);
    });
}

/// Telemetry sink: the byte budget is a hard cap, `total == kept +
/// dropped` at every step, and eviction is strictly drop-oldest — the
/// kept events are always the most recent suffix of the push sequence.
#[test]
fn prop_telemetry_sink_budget_holds() {
    use porter::telemetry::{EventKind, TelemetryEvent, TelemetrySink};
    forall("telemetry-sink-budget", 60, |g: &mut Gen| {
        // floor of 256 bytes: every generated event fits on its own, so
        // the suffix property is exact (no outright-oversized drops)
        let budget = g.u64_in(256, 4096);
        let mut sink = TelemetrySink::new(budget);
        assert!(sink.is_enabled());
        let n = g.usize_in(1, 120);
        for i in 0..n {
            let mut ev = TelemetryEvent::new(EventKind::Queued, i as u64);
            if g.bool() {
                ev = ev.func(&"f".repeat(g.usize_in(1, 64)));
            }
            if g.bool() {
                ev = ev.arg("k", i as u64);
            }
            sink.push(ev);
            assert!(
                sink.used_bytes() <= sink.budget_bytes(),
                "budget exceeded: {} > {}",
                sink.used_bytes(),
                sink.budget_bytes()
            );
            assert_eq!(sink.total_events(), sink.len() as u64 + sink.dropped_events());
        }
        let kept: Vec<u64> = sink.events().map(|e| e.t_ns).collect();
        assert!(!kept.is_empty(), "budget fits at least one event");
        let first = n as u64 - kept.len() as u64;
        for (j, t) in kept.iter().enumerate() {
            assert_eq!(*t, first + j as u64, "eviction must be drop-oldest in push order");
        }
    });
}

/// SoA page table: any sequence of map/touch/migrate/unmap/window ops
/// keeps the flat columns observationally identical to a naive
/// struct-of-maps oracle (per-page views, tier lookups, mapped count,
/// and the mapped-page iteration as a set).
#[test]
fn prop_soa_page_table_matches_map_oracle() {
    use porter::mem::page::{PageMap, PageMeta, Segment, UNMAPPED};
    use std::collections::BTreeMap;
    forall("soa-page-oracle", 60, |g: &mut Gen| {
        let mut pm = PageMap::new(4096);
        let mut oracle: BTreeMap<PageNo, PageMeta> = BTreeMap::new();
        let max_index = 24u32;
        for _ in 0..g.usize_in(1, 120) {
            let p = PageNo {
                segment: if g.bool() { Segment::Heap } else { Segment::Mmap },
                index: g.u64_in(0, max_index as u64) as u32,
            };
            match g.usize_in(0, 4) {
                0 => {
                    let t = if g.bool() { TierKind::Dram } else { TierKind::Cxl };
                    pm.set_tier(p, t);
                    oracle.entry(p).or_insert(UNMAPPED).set_tier(t);
                }
                1 => {
                    pm.touch(p);
                    oracle.entry(p).or_insert(UNMAPPED).touch();
                }
                2 => {
                    let got = pm.touch_and_map(p);
                    let e = oracle.entry(p).or_insert(UNMAPPED);
                    let expected = match e.tier() {
                        Some(k) => (k, false),
                        None => {
                            e.set_tier(TierKind::Dram);
                            (TierKind::Dram, true)
                        }
                    };
                    e.touch();
                    assert_eq!(got, expected, "touch_and_map diverged on {p:?}");
                }
                3 => {
                    pm.unmap(p);
                    oracle.insert(p, UNMAPPED);
                }
                _ => {
                    pm.end_window();
                    for m in oracle.values_mut() {
                        if m.is_mapped() {
                            m.window_accesses = 0;
                            m.idle_ticks = m.idle_ticks.saturating_add(1);
                        }
                    }
                }
            }
        }
        // full observational equality over the op universe (+ a margin
        // of never-touched indices past it)
        for segment in [Segment::Heap, Segment::Mmap] {
            for index in 0..=max_index + 4 {
                let p = PageNo { segment, index };
                let want = oracle.get(&p).copied().unwrap_or(UNMAPPED);
                assert_eq!(pm.get(p), want, "get({p:?}) diverged from the oracle");
                assert_eq!(pm.tier_of(p), want.tier(), "tier_of({p:?}) diverged");
            }
        }
        let want_mapped: Vec<(PageNo, PageMeta)> =
            oracle.iter().filter(|(_, m)| m.is_mapped()).map(|(p, m)| (*p, *m)).collect();
        let mut got_mapped: Vec<(PageNo, PageMeta)> = pm.iter_mapped().collect();
        got_mapped.sort_by_key(|(p, _)| *p);
        assert_eq!(got_mapped, want_mapped, "mapped-page iteration diverged");
        assert_eq!(pm.mapped_count(), want_mapped.len());
    });
}
