//! Integration: the function-lifecycle layer end-to-end — warm pools
//! cutting cold starts, snapshots leasing shared-pool capacity and
//! enabling cross-node restores, and the whole thing deterministic and
//! strictly opt-in (legacy runs are bit-identical with the layer off).

use porter::cluster::simulate;
use porter::config::Config;

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.nodes = 2;
    cfg.cluster.min_nodes = 1;
    cfg.cluster.max_nodes = 4;
    cfg.cluster.functions = 3;
    cfg.cluster.rate_per_s = 400.0;
    cfg.cluster.duration_s = 0.05;
    cfg.cluster.autoscale = false;
    cfg.cluster.seed = 0x11FE;
    cfg
}

fn lifecycle_cfg(warm_pool_bytes: u64, snapshot: bool, policy: &str) -> Config {
    let mut cfg = base_cfg();
    cfg.lifecycle.enabled = true;
    cfg.lifecycle.warm_pool_bytes = warm_pool_bytes;
    cfg.lifecycle.snapshot = snapshot;
    cfg.lifecycle.policy = policy.to_string();
    cfg
}

/// The PR's acceptance scenario: `--warm-pool-mb 512 --snapshot` must
/// report strictly fewer cold starts and lower p50 than the same run
/// with the warm pool disabled, with snapshot/restore bytes visibly
/// debited from the shared CXL pool.
#[test]
fn warm_pool_with_snapshots_beats_disabled_pool() {
    let disabled = simulate(&lifecycle_cfg(0, false, "ttl")).unwrap();
    let warm = simulate(&lifecycle_cfg(512 << 20, true, "ttl")).unwrap();
    assert_eq!(disabled.completed, warm.completed);
    assert!(
        warm.cold_starts < disabled.cold_starts,
        "cold starts {} must be strictly fewer than {}",
        warm.cold_starts,
        disabled.cold_starts
    );
    assert!(
        warm.fleet_p50_ns < disabled.fleet_p50_ns,
        "p50 {} must be strictly lower than {}",
        warm.fleet_p50_ns,
        disabled.fleet_p50_ns
    );
    // snapshot machinery visibly used the shared pool
    assert!(warm.snapshots_taken > 0);
    assert!(warm.snapshot_bytes > 0, "snapshot writes must debit the pool links");
    assert!(warm.snapshot_leased_bytes > 0, "snapshot leases must hold pool capacity");
    assert!(warm.pool_peak_occupancy > 0.0);
    // and the disabled run has no snapshot activity at all
    assert_eq!(disabled.snapshot_bytes, 0);
    assert_eq!(disabled.restores, 0);
}

#[test]
fn every_keepalive_policy_amortizes_cold_starts() {
    for policy in ["ttl", "lru", "histogram"] {
        let zero = simulate(&lifecycle_cfg(0, false, policy)).unwrap();
        let funded = simulate(&lifecycle_cfg(512 << 20, false, policy)).unwrap();
        assert_eq!(zero.cold_starts, zero.completed, "{policy}: zero budget is all-cold");
        assert!(
            funded.warm_starts > 0 && funded.cold_starts < zero.cold_starts,
            "{policy}: funded pool must produce warm starts \
             (cold {} of {}, warm {})",
            funded.cold_starts,
            funded.completed,
            funded.warm_starts
        );
    }
}

#[test]
fn snapshot_only_mode_restores_across_nodes() {
    // zero keep-alive budget but snapshots on: every sandbox demotes to
    // the store on finish, so later arrivals — on either node — restore
    let r = simulate(&lifecycle_cfg(0, true, "ttl")).unwrap();
    assert!(r.restores > 0, "snapshot-only mode must restore");
    assert!(r.restore_bytes > 0);
    assert_eq!(r.cold_starts + r.warm_starts + r.restores, r.completed);
    // restores replay seeded shapes: profile runs stay bounded by
    // node × function even though keep-alive is off
    let max_profiles = (r.nodes.len() * 3) as u64;
    assert!(r.cold_runs <= max_profiles, "{} profile runs", r.cold_runs);
}

#[test]
fn lifecycle_layer_is_opt_in_and_deterministic() {
    // legacy runs are unaffected by the layer existing
    let legacy_a = simulate(&base_cfg()).unwrap();
    let legacy_b = simulate(&base_cfg()).unwrap();
    assert_eq!(legacy_a.determinism_token, legacy_b.determinism_token);
    assert!(!legacy_a.lifecycle_enabled);
    assert_eq!(legacy_a.snapshot_bytes, 0);
    // lifecycle runs are deterministic too, and differ from legacy
    let cfg = lifecycle_cfg(64 << 20, true, "histogram");
    let a = simulate(&cfg).unwrap();
    let b = simulate(&cfg).unwrap();
    assert_eq!(a.determinism_token, b.determinism_token);
    assert_eq!(a.cold_starts, b.cold_starts);
    assert_eq!(a.restores, b.restores);
    assert_eq!(a.snapshot_bytes, b.snapshot_bytes);
    assert_ne!(
        a.determinism_token, legacy_a.determinism_token,
        "explicit sandbox lifetimes must change the virtual timeline"
    );
}

#[test]
fn tiny_snapshot_budget_denies_or_evicts_without_leaking() {
    let mut cfg = lifecycle_cfg(0, true, "ttl");
    // a store capped at a sliver of the pool: admissions must be denied
    // or evict predecessors, never over-lease
    cfg.lifecycle.snapshot_capacity_frac = 1e-6; // ~0.5 MiB of 512 GiB
    let r = simulate(&cfg).unwrap();
    let cap = (cfg.cluster.cxl_pool as f64 * cfg.lifecycle.snapshot_capacity_frac) as u64;
    assert!(
        r.snapshot_leased_bytes <= cap,
        "leased {} exceeds the store budget {}",
        r.snapshot_leased_bytes,
        cap
    );
    assert!(
        r.snapshot_lease_denied > 0 || r.snapshot_evicted > 0 || r.snapshots_taken == 0,
        "a starved store must deny or evict"
    );
}
