//! Integration: the Porter middleware serving real (test-scale)
//! functions through gateway → balancer → server → engine → tuner.

use std::sync::Arc;

use porter::config::Config;
use porter::porter::slo::SloTracker;
use porter::porter::{FunctionSpec, Gateway};
use porter::workloads::registry::{build, Scale};

fn config(servers: usize, workers: usize) -> Config {
    let mut cfg = Config::default();
    cfg.porter.servers = servers;
    cfg.porter.workers_per_server = workers;
    cfg
}

#[test]
fn learning_loop_first_profile_then_hint() {
    let cfg = config(1, 2);
    let mut gw = Gateway::new(&cfg);
    gw.deploy(FunctionSpec::new("kvstore", Arc::from(build("kvstore", Scale::Small).unwrap())));

    let first = gw.invoke("kvstore").unwrap().wait();
    assert!(first.profiled && !first.used_hint);
    gw.tuner.drain();

    let second = gw.invoke("kvstore").unwrap().wait();
    assert!(second.used_hint && !second.profiled);
    assert_eq!(first.checksum, second.checksum, "placement must not change results");
    assert!(second.slo_target_ns.is_some());
    gw.shutdown();
}

#[test]
fn many_functions_many_invocations_all_complete() {
    let cfg = config(2, 3);
    let mut gw = Gateway::new(&cfg);
    let functions = ["json", "chameleon", "compression", "image"];
    for f in functions {
        gw.deploy(FunctionSpec::new(f, Arc::from(build(f, Scale::Small).unwrap())));
    }
    let mut slo = SloTracker::default();
    // burst: 6 rounds × 4 functions, async
    let tickets: Vec<_> = (0..6)
        .flat_map(|_| functions.iter().map(|f| gw.invoke(f).unwrap()))
        .collect();
    let mut checksums = std::collections::HashMap::new();
    for t in tickets {
        let out = t.wait();
        slo.record(&out);
        let e = checksums.entry(out.function.clone()).or_insert(out.checksum);
        assert_eq!(*e, out.checksum, "{}: unstable checksum across invocations", out.function);
    }
    for f in functions {
        assert_eq!(slo.get(f).unwrap().invocations, 6);
    }
    assert_eq!(gw.queue_depths().iter().sum::<usize>(), 0);
    gw.shutdown();
}

#[test]
fn balancer_spreads_load() {
    let cfg = config(3, 1);
    let mut gw = Gateway::new(&cfg);
    gw.deploy(FunctionSpec::new("sort", Arc::from(build("sort", Scale::Small).unwrap())));
    // enqueue a burst without waiting, then check depths are spread
    let tickets: Vec<_> = (0..9).map(|_| gw.invoke("sort").unwrap()).collect();
    let depths = gw.queue_depths();
    assert_eq!(depths.len(), 3);
    let max = *depths.iter().max().unwrap();
    let min = *depths.iter().min().unwrap();
    assert!(max - min <= 2, "unbalanced queues: {depths:?}");
    for t in tickets {
        t.wait();
    }
    gw.shutdown();
}

#[test]
fn slo_targets_tighten_after_first_run() {
    let cfg = config(1, 1);
    let mut gw = Gateway::new(&cfg);
    let mut spec = FunctionSpec::new("json", Arc::from(build("json", Scale::Small).unwrap()));
    spec.slo_factor = 1.25;
    gw.deploy(spec);
    let first = gw.invoke("json").unwrap().wait();
    gw.tuner.drain();
    let second = gw.invoke("json").unwrap().wait();
    let target = second.slo_target_ns.unwrap();
    assert!(
        (target - first.report.wall_ns.min(second.report.wall_ns) * 1.25).abs() / target < 0.3,
        "target {target} not ~1.25× best wall"
    );
    gw.shutdown();
}

#[test]
fn memory_cap_respected_in_grant() {
    let mut cfg = config(1, 1);
    cfg.porter.migration_enabled = false;
    let mut gw = Gateway::new(&cfg);
    let mut spec = FunctionSpec::new("kvstore", Arc::from(build("kvstore", Scale::Small).unwrap()));
    spec.memory_cap_bytes = 8 * cfg.machine.page_bytes; // absurdly tight cap
    gw.deploy(spec);
    let out = gw.invoke("kvstore").unwrap().wait();
    // nearly everything must have landed in CXL
    assert!(
        out.report.peak_dram_bytes <= 16 * cfg.machine.page_bytes,
        "dram grant exceeded cap: {}",
        out.report.peak_dram_bytes
    );
    gw.shutdown();
}

#[test]
fn migration_can_be_disabled() {
    let mut cfg = config(1, 1);
    cfg.porter.migration_enabled = false;
    let mut gw = Gateway::new(&cfg);
    gw.deploy(FunctionSpec::new("kvstore", Arc::from(build("kvstore", Scale::Small).unwrap())));
    let out = gw.invoke("kvstore").unwrap().wait();
    assert_eq!(out.report.promotions, 0);
    assert_eq!(out.report.demotions, 0);
    gw.shutdown();
}
