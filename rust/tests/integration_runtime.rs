//! Integration: the PJRT runtime executing the AOT artifacts, verified
//! against rust-side reference numerics. Skipped cleanly (with a loud
//! message) when `make artifacts` has not run.

use porter::runtime::{ArtifactManifest, MlpParams, ModelRuntime};

fn runtime() -> Option<ModelRuntime> {
    let dir = ArtifactManifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(ModelRuntime::load(dir).expect("runtime must load when artifacts exist"))
}

/// f32 reference MLP forward matching python/compile/model.py.
fn reference_forward(params: &MlpParams, x: &[f32], batch: usize) -> Vec<f32> {
    let mut h: Vec<f32> = x.to_vec();
    let n_layers = params.layers.len();
    for (l, (w, b)) in params.layers.iter().enumerate() {
        let din = params.dims[l];
        let dout = params.dims[l + 1];
        let mut out = vec![0f32; batch * dout];
        for r in 0..batch {
            for k in 0..din {
                let a = h[r * din + k];
                if a == 0.0 {
                    continue;
                }
                let wrow = &w[k * dout..(k + 1) * dout];
                let orow = &mut out[r * dout..(r + 1) * dout];
                for (o, wv) in orow.iter_mut().zip(wrow) {
                    *o += a * wv;
                }
            }
            for (j, o) in out[r * dout..(r + 1) * dout].iter_mut().enumerate() {
                *o += b[j];
                if l + 1 < n_layers && *o < 0.0 {
                    *o = 0.0; // relu on hidden layers
                }
            }
        }
        h = out;
    }
    h
}

#[test]
fn mlp_infer_matches_rust_reference() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.model_layers.clone();
    let params = MlpParams::init(&dims, 11);
    let sig = rt.manifest.get("mlp_infer").unwrap();
    let xin = sig.inputs.last().unwrap();
    let batch = xin.shape[0];
    let x: Vec<f32> = (0..xin.elements()).map(|i| ((i % 31) as f32 - 15.0) * 0.05).collect();
    let got = rt.mlp_infer(&params, &x).unwrap();
    let want = reference_forward(&params, &x, batch);
    assert_eq!(got.len(), want.len());
    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
        assert!(
            (g - w).abs() <= 1e-2 + 1e-3 * w.abs(),
            "logit {i}: pjrt {g} vs reference {w}"
        );
    }
}

#[test]
fn mlp_training_reduces_loss_on_separable_task() {
    let Some(rt) = runtime() else { return };
    let dims = rt.manifest.model_layers.clone();
    let mut params = MlpParams::init(&dims, 3);
    let sig = rt.manifest.get("mlp_train").unwrap();
    let batch = sig.inputs[sig.inputs.len() - 2].shape[0];
    let d_in = dims[0];
    let mut rng = porter::util::prng::Rng::new(77);
    // fixed linear projection defines the labels
    let proj: Vec<f32> = (0..10 * d_in).map(|_| rng.normal() as f32).collect();
    let mut losses = Vec::new();
    for _ in 0..30 {
        let mut x = vec![0f32; batch * d_in];
        let mut y = vec![0i32; batch];
        for b in 0..batch {
            for v in &mut x[b * d_in..(b + 1) * d_in] {
                *v = rng.normal() as f32;
            }
            let xs = &x[b * d_in..(b + 1) * d_in];
            let mut best = (0usize, f32::MIN);
            for c in 0..10 {
                let s: f32 =
                    xs.iter().zip(&proj[c * d_in..(c + 1) * d_in]).map(|(a, b)| a * b).sum();
                if s > best.1 {
                    best = (c, s);
                }
            }
            y[b] = best.0 as i32;
        }
        losses.push(rt.mlp_train_step(&mut params, &x, &y).unwrap());
    }
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(last < first * 0.9, "loss did not fall: {first} → {last} ({losses:?})");
    assert!(losses.iter().all(|l| l.is_finite()));
}

#[test]
fn pallas_matmul_artifact_matches_reference() {
    let Some(rt) = runtime() else { return };
    let sig = rt.manifest.get("matmul").unwrap();
    let n = sig.inputs[0].shape[0];
    let mut rng = porter::util::prng::Rng::new(5);
    let a: Vec<f32> = (0..n * n).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|_| rng.f64_in(-1.0, 1.0) as f32).collect();
    let got = rt.matmul(&a, &b).unwrap();
    // spot-check 64 random entries against the naive product
    for _ in 0..64 {
        let (i, j) = (rng.usize_in(0, n), rng.usize_in(0, n));
        let want: f32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
        let g = got[i * n + j];
        assert!((g - want).abs() <= 1e-3 + 1e-4 * want.abs(), "c[{i}][{j}]: {g} vs {want}");
    }
}

#[test]
fn runtime_rejects_wrong_shapes_and_unknown_artifacts() {
    let Some(rt) = runtime() else { return };
    let params = MlpParams::init(&rt.manifest.model_layers.clone(), 1);
    // empty input can never match the artifact's batch × d_in
    assert!(rt.mlp_infer(&params, &[]).is_err());
    assert!(rt.matmul(&[], &[]).is_err());
    // params whose geometry disagrees with the artifact are rejected
    let mismatched = MlpParams::init(&[8, 4, 2], 1);
    let sig = rt.manifest.get("mlp_infer").unwrap();
    let x = vec![0f32; sig.inputs.last().unwrap().elements()];
    assert!(rt.mlp_infer(&mismatched, &x).is_err());
    // unknown artifact names fail loudly
    assert!(rt.mlp_infer_with("nonexistent", &params, &x).is_err());
    assert!(!rt.has("nonexistent"));
}
