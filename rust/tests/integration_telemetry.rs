//! Integration: virtual-time telemetry end-to-end — a fixed cluster
//! run exports a Chrome-trace JSON (+ CSV time series) that parses
//! back, and enabling telemetry never changes the simulation itself.

use porter::cluster::{simulate, simulate_full};
use porter::config::Config;
use porter::telemetry::export;
use porter::util::json::Json;

fn cfg(telemetry: bool) -> Config {
    let mut cfg = Config::default();
    cfg.cluster.nodes = 2;
    cfg.cluster.functions = 3;
    cfg.cluster.rate_per_s = 400.0;
    cfg.cluster.duration_s = 0.05;
    cfg.cluster.autoscale = false;
    cfg.cluster.seed = 0x7E1E;
    cfg.lifecycle.enabled = true;
    cfg.lifecycle.warm_pool_bytes = 256 * 1024 * 1024;
    cfg.lifecycle.snapshot = true;
    cfg.telemetry.enabled = telemetry;
    cfg.telemetry.epoch_ns = 5_000_000;
    cfg
}

#[test]
fn chrome_trace_roundtrip_on_fixed_cluster_run() {
    let (report, tele) = simulate_full(&cfg(true)).unwrap();
    assert!(report.completed > 0);
    let kinds = tele.sink.kind_counts();
    assert!(kinds.len() >= 4, "expected >= 4 event kinds, got {kinds:?}");
    assert!(tele.series.len() >= 5, "expected >= 5 series, got {}", tele.series.len());

    let doc = tele.to_chrome_json(vec![("note", Json::str("fixture"))]);
    let parsed = Json::parse(&doc.to_string_compact()).unwrap();
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty());
    // every row carries the Chrome trace-event required fields
    for ev in events {
        for key in ["ph", "pid", "tid", "ts", "name"] {
            assert!(ev.get(key).is_some(), "missing {key} in {ev:?}");
        }
    }
    // invocation spans export as complete events with durations
    assert!(events.iter().any(|e| e.get("ph").and_then(Json::as_str) == Some("X")));
    // the summarize rollup reads the exported document back
    let summary = export::summarize(&parsed).unwrap();
    assert!(summary.contains("invocation"), "rollup missing invocation rows:\n{summary}");

    // CSV: long form, one line per point plus the header
    let csv = tele.to_csv();
    let lines: Vec<&str> = csv.lines().collect();
    assert_eq!(lines[0], "series,t_ns,value");
    assert_eq!(lines.len() as u64, 1 + tele.series.points());
}

#[test]
fn telemetry_enabled_run_matches_disabled_run() {
    let base = simulate(&cfg(false)).unwrap();
    let (instrumented, tele) = simulate_full(&cfg(true)).unwrap();
    assert!(tele.sink.total_events() > 0);
    assert_eq!(base.determinism_token, instrumented.determinism_token);
    assert_eq!(base.completed, instrumented.completed);
    assert_eq!(base.fleet_p50_ns, instrumented.fleet_p50_ns);
    assert_eq!(base.fleet_p99_ns, instrumented.fleet_p99_ns);
    assert_eq!(base.cold_starts, instrumented.cold_starts);
    assert_eq!(base.warm_starts, instrumented.warm_starts);
    assert_eq!(base.restores, instrumented.restores);
    assert_eq!(base.snapshot_bytes, instrumented.snapshot_bytes);
    assert!(base.fleet_mean_ns == instrumented.fleet_mean_ns);
    assert!(base.violation_rate == instrumented.violation_rate);
}
