//! Integration: the full §3 pipeline (workload → machine → DAMON →
//! hints → static placement) across modules, at test scale.

use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::monitor::{Damon, ExactHeatmap, Heatmap, TopDown};
use porter::placement::static_place::{profile_and_place, run_plain};
use porter::placement::HeatClass;
use porter::sim::{colocate, Machine};
use porter::trace::{NullSink, TraceRecorder};
use porter::workloads::graph::rmat;
use porter::workloads::kvstore::KvStore;
use porter::workloads::pagerank::PageRank;
use porter::workloads::registry::{suite, Scale, GRAPH_SEED};
use porter::workloads::Workload;

/// Every suite workload: CXL must never be faster than DRAM, and the
/// result must be identical on both tiers.
#[test]
fn suite_cxl_never_faster_and_results_stable() {
    let cfg = Config::default();
    for w in suite(Scale::Small) {
        let (dram, sum_d) = run_plain(&cfg, w.as_ref(), TierKind::Dram);
        let (cxl, sum_c) = run_plain(&cfg, w.as_ref(), TierKind::Cxl);
        assert_eq!(sum_d, sum_c, "{}: tier changed the computation", w.name());
        assert!(
            cxl.wall_ns >= dram.wall_ns * 0.999,
            "{}: cxl ({}) faster than dram ({})",
            w.name(),
            cxl.wall_ns,
            dram.wall_ns
        );
        // accounting sanity
        assert_eq!(dram.cxl_misses, 0, "{}: dram run touched cxl", w.name());
        assert_eq!(cxl.dram_misses, 0, "{}: cxl run touched dram", w.name());
        let b = TopDown::from_report(&dram);
        assert!(b.memory_bound_frac >= 0.0 && b.memory_bound_frac <= 1.0);
    }
}

/// The virtual-time model is exactly deterministic.
#[test]
fn virtual_time_deterministic() {
    let cfg = Config::default();
    let w = KvStore::new(10_000, 50_000);
    let (a, _) = run_plain(&cfg, &w, TierKind::Cxl);
    let (b, _) = run_plain(&cfg, &w, TierKind::Cxl);
    assert_eq!(a.wall_ns, b.wall_ns);
    assert_eq!(a.l3_misses, b.l3_misses);
}

/// Placement pipeline on a kvstore: the zipf-hot slots should make the
/// hint classify at least one object, and hinted must beat pure CXL.
#[test]
fn kvstore_hinted_beats_pure_cxl() {
    let mut cfg = Config::default();
    cfg.porter.dram_budget_frac = 0.5;
    // LLC-busting store
    let w = KvStore::new(1_500_000, 600_000);
    let r = profile_and_place(&cfg, &w);
    assert_eq!(r.checksums[1], r.checksums[2]);
    assert!(
        r.hinted.wall_ns < r.all_cxl.wall_ns,
        "hinted {} vs cxl {}",
        r.hinted.wall_ns,
        r.all_cxl.wall_ns
    );
    assert!(r.hint.objects.iter().any(|o| o.class == HeatClass::Hot));
}

/// DAMON vs exact ground truth: the sampled heatmap must agree with the
/// exact one on where the hot half of the address space is.
#[test]
fn damon_heatmap_tracks_exact_heatmap() {
    let cfg = Config::default();
    let w = PageRank::new(rmat(13, 8, GRAPH_SEED), 3);
    let mut machine = Machine::all_in(&cfg.machine, TierKind::Cxl);
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine.attach_observer(Box::new(Damon::new(&cfg.monitor, cfg.machine.page_bytes, 5)));
    let base = porter::shim::intercept::MMAP_BASE;
    let span = 64 << 20;
    machine.attach_observer(Box::new(ExactHeatmap::new(base, base + span, 32, 1e5)));
    let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut machine);
    w.run(&mut env);
    drop(env);
    let mut obs = machine.take_observers();
    let exact = obs.pop().unwrap().into_any().downcast::<ExactHeatmap>().unwrap().finish();
    let damon = obs.pop().unwrap().into_any().downcast::<Damon>().unwrap();
    let dmap = Heatmap::from_damon(&damon.snapshots, base, base + span, 32, 8);

    // column (address-bin) heat vectors should correlate positively
    let col = |m: &Heatmap, a: usize| -> f64 { (0..m.time_bins).map(|t| m.at(t, a)).sum() };
    let e: Vec<f64> = (0..32).map(|a| col(&exact, a)).collect();
    let d: Vec<f64> = (0..32).map(|a| col(&dmap, a)).collect();
    let hot_exact: Vec<usize> = top_half(&e);
    let hot_damon: Vec<usize> = top_half(&d);
    let overlap = hot_exact.iter().filter(|i| hot_damon.contains(i)).count();
    assert!(
        overlap * 2 >= hot_exact.len(),
        "DAMON hot-bin overlap too low: {overlap}/{}",
        hot_exact.len()
    );
}

fn top_half(xs: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
    idx.truncate(xs.len() / 2);
    idx
}

/// Recorder replay fidelity: record a workload, replay the recording
/// into a second sink — the replay must reproduce the totals of a
/// direct (unrecorded) run of the same deterministic workload exactly.
#[test]
fn trace_recorder_replay_matches_direct_run() {
    let cfg = Config::default();
    let w = KvStore::new(5_000, 25_000);
    // recorded run
    let mut rec = TraceRecorder::new();
    {
        let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut rec);
        w.run(&mut env);
    }
    let trace = rec.finish();
    // direct run into a counting sink
    let mut direct = NullSink::default();
    {
        let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut direct);
        w.run(&mut env);
    }
    // replay into a second sink
    let mut replayed = NullSink::default();
    trace.replay(&mut replayed);
    assert_eq!(replayed.accesses, direct.accesses, "access totals drifted in replay");
    assert_eq!(replayed.bytes, direct.bytes, "byte totals drifted in replay");
    assert_eq!(replayed.compute_cycles, direct.compute_cycles, "compute drifted in replay");
    assert_eq!(replayed.allocs, direct.allocs, "alloc events drifted in replay");
    // the trace's own accessors agree with both
    assert_eq!(trace.n_accesses(), direct.accesses);
    assert_eq!(trace.bytes_accessed(), direct.bytes);
    assert_eq!(trace.compute_cycles(), direct.compute_cycles);
    // replaying a second time is idempotent
    let mut again = NullSink::default();
    trace.replay(&mut again);
    assert_eq!(again.accesses, replayed.accesses);
    assert_eq!(again.bytes, replayed.bytes);
}

/// Colocation: pairwise colocated runs are slower than solo and CXL
/// colocation is worse than DRAM colocation (Fig. 7's invariant) for
/// cache-contending tenants.
#[test]
fn colocation_invariants() {
    let cfg = Config::default();
    let record = |seed: u64| {
        let mut rec = TraceRecorder::new();
        let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut rec);
        let w = KvStore {
            keys: 800_000,
            ops: 120_000,
            theta: 0.6,
            write_frac: 0.2,
            value_words: 4,
            seed,
        };
        w.run(&mut env);
        rec.finish()
    };
    let a = record(1);
    let b = record(2);
    let dram = colocate(&cfg.machine, TierKind::Dram, &[&a, &b], 256);
    let cxl = colocate(&cfg.machine, TierKind::Cxl, &[&a, &b], 256);
    for i in 0..2 {
        assert!(dram.slowdown_pct(i) > -1.0);
        assert!(cxl.slowdown_pct(i) > -1.0);
    }
    let dram_avg = (dram.slowdown_pct(0) + dram.slowdown_pct(1)) / 2.0;
    let cxl_avg = (cxl.slowdown_pct(0) + cxl.slowdown_pct(1)) / 2.0;
    assert!(cxl_avg > dram_avg, "cxl {cxl_avg:.2}% <= dram {dram_avg:.2}%");
}

/// A custom machine config flows through the whole pipeline: with zero
/// CXL latency penalty and equal bandwidth, the tiers behave identically.
#[test]
fn equal_tiers_mean_no_slowdown() {
    let mut cfg = Config::default();
    cfg.machine.cxl_latency_ns = cfg.machine.dram_latency_ns;
    cfg.machine.cxl_bw_gbps = cfg.machine.dram_bw_gbps;
    let w = KvStore::new(200_000, 100_000);
    let (dram, _) = run_plain(&cfg, &w, TierKind::Dram);
    let (cxl, _) = run_plain(&cfg, &w, TierKind::Cxl);
    let sd = cxl.wall_ns / dram.wall_ns - 1.0;
    assert!(sd.abs() < 0.005, "equal tiers produced {sd:.4} slowdown");
}
