//! Integration tests for the per-function DRAM provisioning optimizer
//! (`placement::provision` + the `OfflineTuner` loop that applies it).

use std::sync::Arc;

use porter::config::Config;
use porter::placement::provision::{obtain_curve, BudgetAllocator, FunctionDemand};
use porter::porter::engine::{run_invocation, EngineConfig};
use porter::porter::gateway::FunctionSpec;
use porter::porter::sysload::SystemLoad;
use porter::porter::tuner::OfflineTuner;
use porter::trace::TraceStore;
use porter::workloads::compression::Compression;
use porter::workloads::kvstore::KvStore;
use porter::workloads::Workload;

/// The acceptance scenario: two co-resident functions — one with a
/// strong zipf hot set (kvstore), one streaming its whole input once
/// (compression) — must end up with visibly different DRAM budget
/// fractions under a shared capacity that cannot satisfy both.
#[test]
fn hot_skewed_and_streaming_get_different_budgets() {
    let cfg = Config::default();
    let store = TraceStore::new();
    let kv = KvStore::new(50_000, 200_000);
    let stream = Compression::new(4 << 20);
    let (kv_curve, _) =
        obtain_curve(&store, &kv, &cfg.machine, &cfg.provision.ladder, 16);
    let (st_curve, _) =
        obtain_curve(&store, &stream, &cfg.machine, &cfg.provision.ladder, 16);
    let total = kv_curve.footprint + st_curve.footprint;
    let demands =
        vec![FunctionDemand::new(kv_curve.clone()), FunctionDemand::new(st_curve.clone())];
    let alloc = BudgetAllocator::from_config(&cfg.provision).allocate(total * 3 / 8, &demands);
    let (kv_b, st_b) = (&alloc.budgets[0], &alloc.budgets[1]);
    assert!(alloc.used_bytes <= total * 3 / 8);
    assert!(
        (kv_b.frac - st_b.frac).abs() > 0.1,
        "co-resident hot-skewed vs streaming functions must be provisioned \
         differently: kv frac {:.3} vs stream frac {:.3} (curves: kv {:?} / stream {:?})",
        kv_b.frac,
        st_b.frac,
        kv_curve.points,
        st_curve.points
    );
    // application-specific provisioning never predicts worse than the
    // uniform baseline at equal DRAM
    assert!(alloc.predicted_wall_ns <= alloc.uniform_wall_ns * (1.0 + 1e-9));
}

/// End-to-end through the serving path: with `[provision]` enabled the
/// tuner builds curves from the engine's recorded traces, runs the
/// allocator on the epoch cadence, and keeps producing hints; with it
/// disabled the provisioning counters stay zero.
#[test]
fn tuner_runs_the_provisioning_loop() {
    let mut cfg = Config::default();
    cfg.provision.enabled = true;
    cfg.provision.epoch_profiles = 1;
    // a server small enough that the allocator's choices bind
    cfg.machine.dram_bytes = 4 << 20;
    let sysload = Arc::new(SystemLoad::new(&cfg.machine));
    let tuner = OfflineTuner::new(&cfg);
    let ecfg = EngineConfig::from(&cfg);

    // unique sizes so this test records its own traces in the global
    // store regardless of interleaving
    let kv = FunctionSpec::new("kv-prov", Arc::new(KvStore::new(41_000, 82_000)));
    let st = FunctionSpec::new("stream-prov", Arc::new(Compression::new(3 << 20)));
    let first = run_invocation(1, &kv, &ecfg, &sysload, &tuner);
    assert!(first.profiled);
    tuner.drain();
    let second = run_invocation(2, &st, &ecfg, &sysload, &tuner);
    assert!(second.profiled);
    tuner.drain();

    let (curves, reallocs, _saved) = tuner.provision_metrics().counts();
    assert_eq!(curves, 2, "one demand curve per profiled function");
    assert!(reallocs >= 2, "epoch_profiles = 1 must re-allocate per profile");
    assert!(tuner.hints().get("kv-prov").is_some());
    assert!(tuner.hints().get("stream-prov").is_some());

    // repeat invocations replay under the (possibly re-budgeted) hint
    // and still compute the same result
    let again = run_invocation(3, &kv, &ecfg, &sysload, &tuner);
    assert!(again.used_hint);
    assert_eq!(again.checksum, first.checksum);

    // control: a disabled tuner never touches the provisioning loop
    let off = OfflineTuner::new(&Config::default());
    let _ = run_invocation(4, &kv, &EngineConfig::from(&Config::default()), &sysload, &off);
    off.drain();
    assert_eq!(off.provision_metrics().counts(), (0, 0, 0));
}

/// Real curves from real traces satisfy the curve invariants the
/// property suite checks on synthetic ones.
#[test]
fn real_curves_are_monotone_and_memoized() {
    let cfg = Config::default();
    let store = TraceStore::new();
    let kv = KvStore::new(52_000, 104_000);
    let (curve, built) = obtain_curve(&store, &kv, &cfg.machine, &cfg.provision.ladder, 16);
    assert!(built);
    assert_eq!(curve.points.len(), cfg.provision.ladder.len());
    assert!(curve.points.windows(2).all(|w| w[1].wall_ns <= w[0].wall_ns));
    assert!(curve.points.windows(2).all(|w| w[1].dram_bytes >= w[0].dram_bytes));
    assert!(curve.footprint >= kv.footprint_hint() / 2, "footprint tracks the working set");
    let (curve2, built) = obtain_curve(&store, &kv, &cfg.machine, &cfg.provision.ladder, 16);
    assert!(!built, "second obtain must hit the memo");
    assert!(Arc::ptr_eq(&curve, &curve2));
}
