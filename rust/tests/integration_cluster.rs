//! Integration: the fleet simulation end-to-end — open-loop arrivals
//! routed across nodes, the shared CXL pool arbitrated, hints kept
//! node-local, the autoscaler reacting to load, and the whole run
//! deterministic under a fixed seed.

use porter::cluster::{arrivals_from_config, default_population, simulate, Cluster};
use porter::config::Config;

fn small_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.nodes = 2;
    cfg.cluster.min_nodes = 1;
    cfg.cluster.max_nodes = 4;
    cfg.cluster.functions = 3;
    cfg.cluster.rate_per_s = 400.0;
    cfg.cluster.duration_s = 0.05;
    cfg.cluster.autoscale = false;
    cfg.cluster.seed = 0x5EED;
    cfg
}

#[test]
fn fleet_run_is_deterministic() {
    let cfg = small_cfg();
    let a = simulate(&cfg).unwrap();
    let b = simulate(&cfg).unwrap();
    assert_eq!(a.determinism_token, b.determinism_token);
    // back-to-back runs agree on the whole report, field for field —
    // the determinism-audit bar for the sharded epoch loop
    assert_eq!(a, b);
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.fleet_p99_ns, b.fleet_p99_ns);
    assert_eq!(a.cold_runs, b.cold_runs);
    // a different seed routes differently
    let mut cfg2 = small_cfg();
    cfg2.cluster.seed = 0xBEEF;
    let c = simulate(&cfg2).unwrap();
    assert_ne!(a.determinism_token, c.determinism_token);
}

/// The tentpole acceptance property: random fleet sizes, arrival
/// models, batch widths, and lifecycle toggles — `--shards K` must
/// reproduce the single-thread run bit for bit (full `ClusterReport`
/// equality and token equality) for K in {2, 3, 7}.
#[test]
fn prop_sharded_equals_single_thread() {
    use porter::testing::{forall, Gen};
    forall("sharded-equals-single-thread", 6, |g: &mut Gen| {
        let mut cfg = small_cfg();
        cfg.cluster.nodes = g.usize_in(1, 4);
        cfg.cluster.max_nodes = cfg.cluster.nodes.max(4);
        cfg.cluster.functions = g.usize_in(1, 3);
        cfg.cluster.rate_per_s = g.f64_in(200.0, 800.0);
        cfg.cluster.arrivals = g.pick(&["poisson", "bursty", "diurnal"]).to_string();
        cfg.cluster.seed = g.u64_in(1, 1 << 20);
        cfg.sim.batch_ns = g.u64_in(100_000, 5_000_000);
        if g.bool() {
            cfg.lifecycle.enabled = true;
            cfg.lifecycle.warm_pool_bytes = 128 * 1024 * 1024;
            cfg.lifecycle.snapshot = g.bool();
        }
        let base = simulate(&cfg).unwrap();
        for k in [2, 3, 7] {
            let mut sharded = cfg.clone();
            sharded.sim.shards = k;
            let r = simulate(&sharded).unwrap();
            assert_eq!(r.determinism_token, base.determinism_token, "shards={k} token");
            assert_eq!(r, base, "shards={k} report diverged from single-thread run");
        }
    });
}

#[test]
fn all_arrivals_complete_and_accounting_holds() {
    let cfg = small_cfg();
    let schedule = arrivals_from_config(&cfg).unwrap();
    let r = simulate(&cfg).unwrap();
    assert_eq!(r.completed, schedule.arrivals.len() as u64);
    assert!(r.completed > 0);
    let per_node: u64 = r.nodes.iter().map(|n| n.invocations).sum();
    assert_eq!(per_node, r.completed);
    assert!(r.fleet_p99_ns >= r.fleet_p50_ns);
    assert!(r.throughput_per_s > 0.0);
    assert!(r.node_seconds > 0.0);
    assert!(r.cost_units > 0.0);
    assert!((0.0..=1.0).contains(&r.violation_rate));
    assert!((0.0..=1.0).contains(&r.pool_peak_occupancy));
}

#[test]
fn hints_are_node_local_and_bounded() {
    let mut cfg = small_cfg();
    cfg.cluster.rate_per_s = 1000.0; // ~50 arrivals
    let r = simulate(&cfg).unwrap();
    // each node profiles a function at most once: cold runs are bounded
    // by nodes × functions, and the rest of the fleet traffic is warm
    let max_cold = (r.nodes.len() * cfg.cluster.functions) as u64;
    assert!(r.cold_runs <= max_cold, "cold {} > bound {max_cold}", r.cold_runs);
    assert!(
        r.completed > r.cold_runs * 2,
        "most invocations should be warm: {} cold of {}",
        r.cold_runs,
        r.completed
    );
}

/// Calibrated overload: measure the fleet's mean service time first, so
/// the offered load is guaranteed past one node's capacity whatever the
/// workloads' virtual service times turn out to be.
fn overload_rate(base: &Config, factor: f64) -> f64 {
    let mut cal = base.clone();
    cal.cluster.nodes = 1;
    cal.cluster.autoscale = false;
    cal.cluster.rate_per_s = 500.0;
    cal.cluster.duration_s = 0.2;
    let r = simulate(&cal).unwrap();
    let mean_service_s = (r.mean_service_ns / 1e9).max(1e-6);
    let workers =
        (base.cluster.servers_per_node * base.cluster.workers_per_server) as f64;
    factor * workers / mean_service_s
}

#[test]
fn autoscaler_grows_fleet_under_overload() {
    let mut cfg = small_cfg();
    cfg.cluster.nodes = 1;
    cfg.cluster.max_nodes = 4;
    cfg.cluster.autoscale = true;
    cfg.cluster.autoscale_interval_ns = 5_000_000; // 5 ms
    cfg.cluster.cooldown_ns = 10_000_000;
    cfg.cluster.rate_per_s = overload_rate(&cfg, 6.0);
    cfg.cluster.duration_s = 0.1;
    let r = simulate(&cfg).unwrap();
    assert!(
        !r.events.is_empty(),
        "overload produced no autoscaler events: wait {}",
        r.mean_wait_ns
    );
    assert!(r.nodes.len() > 1, "fleet never grew past one node");
    // and the grown fleet still completed everything
    let schedule = arrivals_from_config(&cfg).unwrap();
    assert_eq!(r.completed, schedule.arrivals.len() as u64);
}

#[test]
fn more_nodes_relieve_queueing_under_fixed_load() {
    let mut cfg = small_cfg();
    cfg.cluster.rate_per_s = overload_rate(&cfg, 3.0);
    cfg.cluster.duration_s = 0.05;
    cfg.cluster.nodes = 1;
    let one = simulate(&cfg).unwrap();
    cfg.cluster.nodes = 4;
    cfg.cluster.max_nodes = 4;
    let four = simulate(&cfg).unwrap();
    assert!(
        four.mean_wait_ns <= one.mean_wait_ns * 1.05 + 10_000.0,
        "4 nodes queued worse than 1: {} vs {}",
        four.mean_wait_ns,
        one.mean_wait_ns
    );
}

#[test]
fn tiny_pool_is_contended() {
    let mut big = small_cfg();
    big.cluster.seed = 3;
    // scarce node DRAM forces real CXL spill, so invocations actually
    // lease from the shared pool
    big.cluster.dram_per_node = 4 << 20;
    let mut tiny = big.clone();
    tiny.cluster.cxl_pool = 256 << 10; // 256 KiB shared across the fleet
    let r_big = simulate(&big).unwrap();
    let r_tiny = simulate(&tiny).unwrap();
    assert!(r_tiny.pool_peak_occupancy >= r_big.pool_peak_occupancy);
    // capacity pressure surfaces as leases that wait or come up short
    assert!(
        r_tiny.pool_shortages > 0 || r_tiny.mean_wait_ns >= r_big.mean_wait_ns,
        "tiny pool showed no pressure"
    );
}

#[test]
fn migration_traffic_debits_the_cxl_link() {
    // DRAM-starved nodes force the kvstore's footprint into CXL; with
    // the engine on, the fleet must log migrations whose bytes ride the
    // nodes' CXL links (added to record_traffic alongside demand bytes)
    use porter::cluster::arrivals::{synthetic, Shape};
    let mut cfg = small_cfg();
    cfg.cluster.dram_per_node = 64 * cfg.machine.page_bytes; // 256 KiB
    cfg.migration.epoch_ticks = 1;
    let names = vec!["kvstore".to_string()];
    let schedule = synthetic(Shape::Poisson, &names, 400.0, 0.05, 0.0, 7);
    assert!(!schedule.arrivals.is_empty());

    let with = Cluster::new(&cfg, &names).unwrap().run(&schedule);
    assert!(
        with.promotions > 0,
        "starved DRAM + hot pages should drive promotions in the fleet"
    );
    assert_eq!(
        with.migration_bytes,
        (with.promotions + with.demotions) * cfg.machine.page_bytes,
        "migration link traffic must match applied moves"
    );

    let mut off = cfg.clone();
    off.migration.policy = "none".to_string();
    let without = Cluster::new(&off, &names).unwrap().run(&schedule);
    assert_eq!(without.promotions, 0);
    assert_eq!(without.migration_bytes, 0);
}

#[test]
fn replay_arrivals_drive_the_fleet() {
    let mut cfg = small_cfg();
    cfg.cluster.arrivals = "replay".into();
    cfg.cluster.trace_path = String::new(); // synthesized demo trace
    let r = simulate(&cfg).unwrap();
    assert!(r.completed > 0);
    let again = simulate(&cfg).unwrap();
    assert_eq!(r.determinism_token, again.determinism_token);
}

#[test]
fn population_and_bad_names() {
    assert_eq!(default_population(3).len(), 3);
    let cfg = small_cfg();
    assert!(Cluster::new(&cfg, &["no-such-fn".to_string()]).is_err());
}
