//! Fig. 7 — "Percent of slowdown in local DRAM and CXL for different
//! colocated functions. CXL always shows more severe impact compared to
//! local DRAM."
//!
//! DL serving colocated with {DL serving, DL training, matmul}; each
//! pair replayed interleaved through the shared machine (shared LLC +
//! shared per-tier bandwidth), all-DRAM vs all-CXL, slowdown relative to
//! running standalone.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench fig7_colocation

use porter::bench::{BenchSuite, FigureReport};
use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::sim::colocate;
use porter::trace::{record_workload, AccessTrace};
use porter::workloads::dl::{DlServe, DlTrain};
use porter::workloads::matmul::MatMul;

fn main() {
    let quick = porter::bench::quick_mode();
    let cfg = Config::default();
    // ResNet-scale weights (80MiB/tenant) so tenants genuinely contend;
    // see examples/colocation.rs for the same scenario with commentary.
    // Each tenant's Trace-IR is recorded once; every colocation cell
    // (pair × tier) is a relocated replay of the same recordings. Quick
    // mode additionally truncates the training stream instead of
    // re-recording a smaller instance.
    let layers = vec![768, 4096, 4096, 10];
    let (req, mm_n) = if quick { (6, 512) } else { (30, 1536) };
    let serve = record_workload(
        &DlServe { layers: layers.clone(), batch: 8, requests: req, flops_per_cycle: 16 },
        cfg.machine.page_bytes,
    );
    let full_train = record_workload(
        &DlTrain { layers: layers.clone(), batch: 64, steps: 4, flops_per_cycle: 16 },
        cfg.machine.page_bytes,
    );
    let train: AccessTrace =
        if quick { full_train.truncated(full_train.len() / 4) } else { full_train };
    let mm = record_workload(&MatMul::new(mm_n), cfg.machine.page_bytes);

    let mut bench = BenchSuite::new("fig7: colocation slowdown, DRAM vs CXL");
    let mut fig = FigureReport::new(
        "Figure 7",
        "dl_serve slowdown (%) when colocated, vs running standalone",
        &["cxl_slowdown_pct", "dram_slowdown_pct"],
    );
    let pairs: [(&str, &AccessTrace); 3] =
        [("with dl_serve", &serve), ("with dl_train", &train), ("with matmul", &mm)];
    let mut all_hold = true;
    for (label, other) in pairs {
        let dram = colocate(&cfg.machine, TierKind::Dram, &[&serve, other], 256);
        let cxl = colocate(&cfg.machine, TierKind::Cxl, &[&serve, other], 256);
        let (d, c) = (dram.slowdown_pct(0), cxl.slowdown_pct(0));
        eprintln!("  {label:14} dram +{d:.1}%  cxl +{c:.1}%");
        fig.row(label, vec![c, d]);
        all_hold &= c > d;
    }
    bench.section(fig.render());
    bench.section(format!(
        "shape: CXL > DRAM for every pair — {}\n\
         paper: \"colocating in CXL always shows more impact on slowdown compared to local DRAM\"",
        if all_hold { "OK" } else { "VIOLATED" }
    ));
    bench.run();
}
