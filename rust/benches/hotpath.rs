//! Hot-path microbenches — the §Perf optimization targets.
//!
//! The simulator's inner loop (workload emit → cache → page table → tier
//! cost) bounds every experiment's wall time; DAMON sampling and trace
//! record/replay are the secondary paths. Run before/after each perf
//! change and record deltas in EXPERIMENTS.md §Perf.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench hotpath

use porter::bench::{BenchConfig, BenchSuite};
use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::monitor::Damon;
use porter::sim::{Cache, Machine};
use porter::trace::{NullSink, TraceRecorder};
use porter::util::prng::Rng;

fn main() {
    let cfg = Config::default();
    let mut bench = BenchSuite::new("hotpath: simulator inner loops").with_config(BenchConfig {
        warmup_iters: 2,
        sample_iters: 8,
        max_time: std::time::Duration::from_secs(60),
    });

    const N_ACCESS: usize = 2_000_000;

    // 1. pure emit overhead (Env + NullSink): the workload-side floor
    bench.bench_with_throughput("env_emit_null_sink", N_ACCESS as f64, "access", || {
        let mut sink = NullSink::default();
        let mut env = porter::shim::Env::new(4096, &mut sink);
        let v = env.tvec::<u64>(1 << 16, 0, "buf");
        let mut i = 0usize;
        for _ in 0..N_ACCESS {
            std::hint::black_box(v.get(i & 0xFFFF, &mut env));
            i = i.wrapping_add(7919);
        }
        sink.accesses
    });

    // 2. machine, all-hit regime (small working set)
    bench.bench_with_throughput("machine_l3_hits", N_ACCESS as f64, "access", || {
        let mut m = Machine::all_in(&cfg.machine, TierKind::Dram);
        let mut env = porter::shim::Env::new(4096, &mut m);
        let v = env.tvec::<u64>(1 << 14, 0, "buf");
        let mut i = 0usize;
        for _ in 0..N_ACCESS {
            std::hint::black_box(v.get(i & 0x3FFF, &mut env));
            i = i.wrapping_add(7919);
        }
        drop(env);
        m.report().accesses
    });

    // 3. machine, miss-heavy regime (random over 64MiB)
    bench.bench_with_throughput("machine_l3_misses", N_ACCESS as f64, "access", || {
        let mut m = Machine::all_in(&cfg.machine, TierKind::Cxl);
        let mut env = porter::shim::Env::new(4096, &mut m);
        let v = env.tvec::<u64>(8 << 20, 0, "buf");
        let mut rng = Rng::new(42);
        for _ in 0..N_ACCESS {
            std::hint::black_box(v.get(rng.usize_in(0, 8 << 20), &mut env));
        }
        drop(env);
        m.report().accesses
    });

    // 4. machine with DAMON attached (profiling overhead)
    bench.bench_with_throughput("machine_with_damon", N_ACCESS as f64, "access", || {
        let mut m = Machine::all_in(&cfg.machine, TierKind::Cxl);
        m.attach_observer(Box::new(Damon::new(&cfg.monitor, 4096, 7)));
        let mut env = porter::shim::Env::new(4096, &mut m);
        let v = env.tvec::<u64>(8 << 20, 0, "buf");
        let mut rng = Rng::new(42);
        for _ in 0..N_ACCESS {
            std::hint::black_box(v.get(rng.usize_in(0, 8 << 20), &mut env));
        }
        drop(env);
        m.report().accesses
    });

    // 5. raw cache loop
    bench.bench_with_throughput("cache_access_line", N_ACCESS as f64, "access", || {
        let mut c = Cache::new(cfg.machine.l3_bytes, 64, 11);
        let mut rng = Rng::new(9);
        let mut hits = 0u64;
        for _ in 0..N_ACCESS {
            if c.access_line(rng.gen_range(1 << 20)) {
                hits += 1;
            }
        }
        hits
    });

    // 5b. byte-span path: one-line accesses take the first==last
    //     early-out in Cache::access; straddling accesses walk the
    //     two-line loop. The pair tracks the fast path's win.
    bench.bench_with_throughput("cache_access_bytes_one_line", N_ACCESS as f64, "access", || {
        let mut c = Cache::new(cfg.machine.l3_bytes, 64, 11);
        let mut rng = Rng::new(9);
        let mut hits = 0u64;
        for _ in 0..N_ACCESS {
            // line-aligned 8-byte reads: never straddle
            let addr = rng.gen_range(1 << 20) * 64;
            let (h, _) = c.access(addr, 8, |_| {});
            hits += h as u64;
        }
        hits
    });
    bench.bench_with_throughput("cache_access_bytes_straddle", N_ACCESS as f64, "access", || {
        let mut c = Cache::new(cfg.machine.l3_bytes, 64, 11);
        let mut rng = Rng::new(9);
        let mut hits = 0u64;
        for _ in 0..N_ACCESS {
            // 8-byte reads crossing every line boundary: two-line loop
            let addr = rng.gen_range(1 << 20) * 64 + 60;
            let (h, _) = c.access(addr, 8, |_| {});
            hits += h as u64;
        }
        hits
    });

    // 6. trace record + replay
    bench.bench_with_throughput("trace_record", N_ACCESS as f64, "event", || {
        let mut rec = TraceRecorder::new();
        let mut env = porter::shim::Env::new(4096, &mut rec);
        let v = env.tvec::<u64>(1 << 16, 0, "buf");
        let mut i = 0usize;
        for _ in 0..N_ACCESS {
            std::hint::black_box(v.get(i & 0xFFFF, &mut env));
            i = i.wrapping_add(7919);
        }
        drop(env);
        rec.finish().len()
    });
    let trace = {
        let mut rec = TraceRecorder::new();
        let mut env = porter::shim::Env::new(4096, &mut rec);
        let v = env.tvec::<u64>(1 << 16, 0, "buf");
        let mut i = 0usize;
        for _ in 0..N_ACCESS {
            std::hint::black_box(v.get(i & 0xFFFF, &mut env));
            i = i.wrapping_add(7919);
        }
        drop(env);
        rec.finish()
    };
    bench.bench_with_throughput("trace_replay_into_machine", trace.len() as f64, "event", || {
        let mut m = Machine::all_in(&cfg.machine, TierKind::Dram);
        trace.replay(&mut m);
        m.report().accesses
    });

    // 7. trace-IR serialization round-trip (delta-encoded JSON) over a
    //    truncated stream — the `porter-cli trace record --out` path
    let ir_slice = trace.truncated(100_000);
    bench.bench_with_throughput("trace_ir_json_roundtrip", ir_slice.len() as f64, "event", || {
        let text = ir_slice.to_json().to_string_compact();
        let parsed = porter::util::json::Json::parse(&text).unwrap();
        porter::trace::AccessTrace::from_json(&parsed).unwrap().len()
    });

    // 8. the fleet DES itself — the epoch-batched sharded loop. The
    //    events/sec trajectory here is what the tentpole refactor
    //    optimizes; the 1-vs-4-shard pair exposes the threading win
    //    (identical simulation by construction, so the delta is pure
    //    host speed). Profile runs amortize through the process-wide
    //    trace store, so steady-state iterations measure the DES.
    let mut fleet = Config::default();
    fleet.cluster.nodes = 4;
    fleet.cluster.functions = 3;
    fleet.cluster.rate_per_s = 2000.0;
    fleet.cluster.duration_s = if porter::bench::quick_mode() { 0.05 } else { 0.2 };
    fleet.cluster.autoscale = false;
    fleet.cluster.seed = 11;
    let n_events = porter::cluster::simulate(&fleet).unwrap().completed;
    for shards in [1usize, 4] {
        let mut cfg = fleet.clone();
        cfg.sim.shards = shards;
        bench.bench_with_throughput(
            &format!("cluster_des_shards_{shards}"),
            n_events as f64,
            "event",
            move || porter::cluster::simulate(&cfg).unwrap().completed,
        );
    }

    bench.run();
}
