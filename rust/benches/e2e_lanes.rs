//! End-to-end lane-scheduler bench: lane count × stride prefetch × DRAM
//! ratio on `txn_bench`, against the pure-migration arms at the same
//! DRAM budget.
//!
//! The contract under test: with most of the working set CXL-resident,
//! independent-transaction lanes overlap CXL stalls with other lanes'
//! compute — `lanes=4 --prefetch` must strictly beat the serial
//! `lanes=1` wall at ≤25% DRAM — while a fleet run with lanes on stays
//! bit-identical across `--shards 1` and `--shards 4`. Writes
//! `BENCH_lanes.json` at the repo root.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_lanes

use porter::bench::{fmt_ns, BenchSuite, FigureReport};
use porter::config::Config;
use porter::mem::migrate::MigrationEngine;
use porter::placement::policies::FirstTouchDram;
use porter::sim::machine::RunReport;
use porter::sim::Machine;
use porter::trace::{record_workload, AccessTrace};
use porter::util::json::Json;
use porter::workloads::txn_bench::TxnBench;
use porter::workloads::Workload;

const LANE_COUNTS: [usize; 4] = [1, 2, 4, 8];
const DRAM_RATIOS: [f64; 2] = [0.125, 0.25];

/// One machine cell: DRAM capped at `ratio` × footprint, first-touch
/// placement, lanes/prefetcher per the cell, the recorded stream
/// replayed. `policy` attaches the epoch migration engine instead (the
/// pure-migration arm runs serial: lanes = 1, no prefetch).
fn run_cell(
    trace: &AccessTrace,
    footprint: u64,
    cfg: &Config,
    ratio: f64,
    lanes: usize,
    prefetch: bool,
    policy: Option<&str>,
) -> RunReport {
    let mut mcfg = cfg.machine.clone();
    let footprint = footprint.max(mcfg.page_bytes);
    mcfg.dram_bytes =
        ((footprint as f64 * ratio) as u64 / mcfg.page_bytes).max(4) * mcfg.page_bytes;
    let mut machine = Machine::new(&mcfg, Box::new(FirstTouchDram::default()));
    if let Some(policy) = policy {
        let mut migration = cfg.migration.clone();
        migration.policy = policy.to_string();
        migration.enabled = true;
        if let Some(engine) = MigrationEngine::from_config(&migration) {
            machine.set_migrator(Box::new(engine));
        }
        machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    }
    if lanes > 1 {
        machine.set_lanes(lanes);
    }
    if prefetch {
        machine.set_prefetcher(cfg.lanes.prefetch_degree, cfg.lanes.prefetch_distance);
    }
    machine.replay(trace);
    machine.report()
}

/// Stall time hidden as a fraction of the serial-equivalent wall — the
/// `*overlap*` metric bench_check bounds to [0, 1].
fn overlap_frac(r: &RunReport) -> f64 {
    let serial = r.wall_ns + r.overlapped_ns;
    if serial <= 0.0 {
        0.0
    } else {
        r.overlapped_ns / serial
    }
}

fn main() {
    let quick = porter::bench::quick_mode();
    let cfg = Config::default();
    let mut suite = BenchSuite::new("e2e: lane-based latency hiding (sim/lanes + sim/prefetch)");

    // the stock table must exceed the 19.25 MB LLC even in quick mode —
    // a cache-resident instance has no stalls to hide
    let w = if quick {
        TxnBench::new(400_000, 40_000)
    } else {
        TxnBench::new(400_000, 200_000)
    };
    let footprint = w.footprint_hint();
    let trace = record_workload(&w, cfg.machine.page_bytes);
    eprintln!(
        "txn_bench: footprint {} trace {} events",
        porter::util::bytes::fmt_bytes(footprint),
        trace.len()
    );

    let mut fig = FigureReport::new(
        "lane-sweep",
        "wall vs serial (%) per (DRAM ratio, lanes, prefetch) + migration arms",
        &["wall_ms", "speedup_vs_serial_pct", "overlap_frac", "prefetch_useful"],
    );
    let mut series = Vec::new();
    for &ratio in &DRAM_RATIOS {
        // serial baseline and the pure-migration arms at this budget
        let serial = run_cell(&trace, footprint, &cfg, ratio, 1, false, None);
        let mut cells: Vec<(String, RunReport)> = Vec::new();
        for &lanes in &LANE_COUNTS {
            for prefetch in [false, true] {
                if lanes == 1 && !prefetch {
                    cells.push(("lanes=1".into(), serial.clone()));
                    continue;
                }
                let r = run_cell(&trace, footprint, &cfg, ratio, lanes, prefetch, None);
                let label = format!("lanes={lanes}{}", if prefetch { "+prefetch" } else { "" });
                cells.push((label, r));
            }
        }
        for policy in ["tpp", "hybrid"] {
            let r = run_cell(&trace, footprint, &cfg, ratio, 1, false, Some(policy));
            cells.push((format!("mig:{policy}"), r));
        }
        for (label, r) in &cells {
            let speedup_pct = (1.0 - r.wall_ns / serial.wall_ns) * 100.0;
            eprintln!(
                "  dram={ratio}/{label}: wall {} ({:+.1}% vs serial) overlap {} \
                 pf {}/{} useful",
                fmt_ns(r.wall_ns),
                -speedup_pct,
                fmt_ns(r.overlapped_ns),
                r.prefetch_useful,
                r.prefetch_issued
            );
            fig.row(
                &format!("dram={ratio}/{label}"),
                vec![
                    r.wall_ns / 1e6,
                    speedup_pct,
                    overlap_frac(r),
                    r.prefetch_useful as f64,
                ],
            );
            series.push(Json::obj(vec![
                ("workload", Json::str("txn_bench")),
                ("dram_ratio", Json::num(ratio)),
                ("config", Json::str(label.clone())),
                ("wall_ns", Json::num(r.wall_ns)),
                ("speedup_vs_serial_pct", Json::num(speedup_pct)),
                ("stall_ns", Json::num(r.stall_ns)),
                ("overlapped_ns", Json::num(r.overlapped_ns)),
                ("overlap_frac", Json::num(overlap_frac(r))),
                ("lane_switches", Json::num(r.lane_switches as f64)),
                ("prefetch_issued", Json::num(r.prefetch_issued as f64)),
                ("prefetch_useful", Json::num(r.prefetch_useful as f64)),
            ]));
        }
        // the acceptance bar: pipelining must strictly beat serial
        // execution when the working set is mostly CXL-resident
        let laned = &cells.iter().find(|(l, _)| l == "lanes=4+prefetch").expect("cell").1;
        assert!(
            laned.wall_ns < serial.wall_ns,
            "dram={ratio}: lanes=4+prefetch ({}) must beat lanes=1 ({})",
            laned.wall_ns,
            serial.wall_ns
        );
        assert!(laned.overlapped_ns > 0.0, "dram={ratio}: lanes must overlap stalls");
        assert!(laned.lane_switches > 0);
        let f = overlap_frac(laned);
        assert!((0.0..=1.0).contains(&f), "overlap_frac {f} out of range");
    }

    // fleet arm: lanes + prefetch on across a 2-node cluster must stay
    // bit-identical across shard counts (report AND token)
    let mut fleet = Config::default();
    fleet.cluster.nodes = 2;
    fleet.cluster.functions = 2;
    fleet.cluster.rate_per_s = 300.0;
    fleet.cluster.duration_s = 0.05;
    fleet.cluster.autoscale = false;
    fleet.cluster.seed = 0x1A9E;
    fleet.lanes.enabled = true;
    fleet.lanes.prefetch = true;
    let r1 = porter::cluster::simulate(&fleet).expect("fleet run");
    let mut sharded = fleet.clone();
    sharded.sim.shards = 4;
    let r4 = porter::cluster::simulate(&sharded).expect("sharded fleet run");
    assert_eq!(
        r1.determinism_token, r4.determinism_token,
        "laned fleet token diverged across shard counts"
    );
    assert_eq!(r1, r4, "laned fleet report diverged across shard counts");
    assert!(r1.lanes_enabled);
    assert!(r1.overlapped_ns > 0.0, "fleet lanes must overlap stalls");
    eprintln!(
        "fleet: {} invocations, overlap {} across shards 1 and 4 (token {:#018x})",
        r1.completed,
        fmt_ns(r1.overlapped_ns),
        r1.determinism_token
    );
    series.push(Json::obj(vec![
        ("workload", Json::str("fleet(2 nodes)")),
        ("config", Json::str("cluster lanes+prefetch shards 1==4")),
        ("completed", Json::num(r1.completed as f64)),
        ("overlapped_ns", Json::num(r1.overlapped_ns)),
        ("lane_switches", Json::num(r1.lane_switches as f64)),
        ("fleet_p50_ns", Json::num(r1.fleet_p50_ns as f64)),
        ("determinism_token", Json::str(format!("{:#018x}", r1.determinism_token))),
    ]));

    suite.section(fig.render());

    let out = Json::obj(vec![
        ("suite", Json::str("e2e_lanes")),
        ("quick", Json::Bool(quick)),
        ("lane_counts", Json::arr(LANE_COUNTS.iter().map(|l| Json::num(*l as f64)))),
        ("dram_ratios", Json::arr(DRAM_RATIOS.iter().map(|r| Json::num(*r)))),
        ("series", Json::Arr(series)),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_lanes.json").into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    suite.run();
}
