//! Per-function DRAM provisioning sweep: uniform vs optimized budgets
//! across workload mixes × DRAM capacities.
//!
//! Setup per cell: a mix of registry functions shares a fixed DRAM
//! capacity (a fraction of the mix's total footprint). The *uniform*
//! arm gives every function the same ladder ratio — the global
//! `dram_budget_frac` the tuner used before the provisioning optimizer
//! existed. The *optimized* arm runs `placement::provision`'s
//! `BudgetAllocator` (greedy marginal-utility descent over each
//! function's Trace-IR demand curve). Both arms are then *measured* by
//! replaying each function's canonical trace at its granted budget —
//! the same what-if machine the curves were built on, so predicted and
//! measured walls agree exactly and the comparison is deterministic.
//!
//! The acceptance claim asserted per mix: optimized beats uniform on at
//! least one axis — lower mean/p50 wall at equal DRAM, or equal wall
//! with measurably less DRAM (`dram_saved_mb`).
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_provision

use porter::bench::{fmt_ns, BenchSuite, FigureReport};
use porter::config::Config;
use porter::placement::provision::{measure_wall, obtain_curve, BudgetAllocator, FunctionDemand};
use porter::trace::TraceStore;
use porter::util::bytes::MIB;
use porter::util::json::Json;
use porter::util::stats::Summary;
use porter::workloads::registry::{build, Scale};

const MIXES: [(&str, &[&str]); 3] = [
    ("hot+stream", &["kvstore", "dl_train"]),
    ("serving", &["json", "kvstore", "chameleon"]),
    ("graph+kv", &["pagerank", "kvstore", "compression"]),
];
const CAPACITY_FRACS: [f64; 2] = [0.25, 0.5];

fn main() {
    let quick = porter::bench::quick_mode();
    let scale = if quick { Scale::Small } else { Scale::Default };
    let cfg = Config::default();
    let store = TraceStore::global();
    let ladder = &cfg.provision.ladder;
    let mut suite = BenchSuite::new("e2e: per-function DRAM provisioning (placement/provision)");

    let mut fig = FigureReport::new(
        "provision-sweep",
        "uniform vs optimized budgets per (mix, capacity fraction)",
        &["latency_delta_pct", "dram_saved_mb", "uniform_wall_ms", "optimized_wall_ms"],
    );
    let mut series = Vec::new();
    for (mix_name, functions) in MIXES {
        // curves + traces, memoized process-wide (kvstore repeats
        // across mixes cost nothing after the first)
        let mut demands = Vec::new();
        let mut traces = Vec::new();
        for name in functions {
            let w = build(name, scale).expect("registry workload");
            let (curve, _) =
                obtain_curve(store, w.as_ref(), &cfg.machine, ladder, cfg.trace.max_cached);
            let (trace, _) = store.obtain(w.as_ref(), cfg.machine.page_bytes, cfg.trace.max_cached);
            demands.push(FunctionDemand::new(curve));
            traces.push(trace);
        }
        let total: u64 = demands.iter().map(|d| d.curve.footprint).sum();
        let mut mix_improved = false;
        for &frac in &CAPACITY_FRACS {
            let capacity = (total as f64 * frac) as u64;
            let alloc = BudgetAllocator::from_config(&cfg.provision).allocate(capacity, &demands);
            // measure both arms for real on the what-if machine
            let uniform_bytes: Vec<u64> = demands
                .iter()
                .map(|d| {
                    d.curve
                        .points
                        .iter()
                        .find(|p| p.ratio == alloc.uniform_ratio)
                        .map(|p| p.dram_bytes)
                        .expect("uniform ratio is a ladder point")
                })
                .collect();
            let uni_walls: Vec<f64> = traces
                .iter()
                .zip(&uniform_bytes)
                .map(|(t, &b)| measure_wall(t, &cfg.machine, b))
                .collect();
            let opt_walls: Vec<f64> = traces
                .iter()
                .zip(&alloc.budgets)
                .map(|(t, b)| measure_wall(t, &cfg.machine, b.dram_bytes))
                .collect();
            let uni = Summary::of(&uni_walls);
            let opt = Summary::of(&opt_walls);
            let uni_total: f64 = uni_walls.iter().sum();
            let opt_total: f64 = opt_walls.iter().sum();
            let uni_used: u64 = uniform_bytes.iter().sum();
            let opt_used = alloc.used_bytes;
            let saved_mb = uni_used.saturating_sub(opt_used) / MIB;
            eprintln!(
                "  {mix_name}/{frac}: uniform {} vs optimized {} wall, {} vs {} MiB used \
                 (saved {saved_mb} MiB{})",
                fmt_ns(uni_total),
                fmt_ns(opt_total),
                uni_used / MIB,
                opt_used / MIB,
                if alloc.fell_back_to_uniform { ", fell back" } else { "" }
            );
            // the acceptance gate, on the allocator's own (clamped)
            // curve walls — structural, holds in every cell
            assert!(
                alloc.predicted_wall_ns <= alloc.uniform_wall_ns * (1.0 + 1e-9),
                "{mix_name}/{frac}: predicted {} worse than uniform {}",
                alloc.predicted_wall_ns,
                alloc.uniform_wall_ns
            );
            // re-measured raw walls may sit slightly above the clamped
            // curve (DemandCurve::new flattens non-monotone placement
            // artifacts), so the measured comparison gets that slack
            assert!(
                opt_total <= uni_total * 1.02,
                "{mix_name}/{frac}: measured optimized wall {opt_total} worse than uniform \
                 {uni_total} beyond the clamp slack"
            );
            assert!(opt_used <= capacity, "{mix_name}/{frac}: allocator over-committed");
            // ...and strictly better on at least one axis somewhere
            if opt_total < uni_total * 0.999 || opt_used < uni_used {
                mix_improved = true;
            }
            let delta_pct = if uni_total > 0.0 {
                (opt_total / uni_total - 1.0) * 100.0
            } else {
                0.0
            };
            fig.row(
                &format!("{mix_name}/cap={frac}"),
                vec![
                    delta_pct,
                    saved_mb as f64,
                    uni_total / 1e6,
                    opt_total / 1e6,
                ],
            );
            series.push(Json::obj(vec![
                ("mix", Json::str(mix_name)),
                ("dram_ratio", Json::num(frac)),
                ("capacity_mb", Json::num((capacity / MIB) as f64)),
                ("uniform_used_mb", Json::num((uni_used / MIB) as f64)),
                ("optimized_used_mb", Json::num((opt_used / MIB) as f64)),
                ("dram_saved_mb", Json::num(saved_mb as f64)),
                ("uniform_wall_ns", Json::num(uni_total)),
                ("optimized_wall_ns", Json::num(opt_total)),
                ("uniform_mix_p50_ns", Json::num(uni.p50)),
                ("optimized_mix_p50_ns", Json::num(opt.p50)),
                ("latency_delta_pct", Json::num(delta_pct)),
                ("fell_back", Json::Bool(alloc.fell_back_to_uniform)),
            ]));
        }
        assert!(
            mix_improved,
            "{mix_name}: optimized never beat uniform on any axis at any capacity"
        );
    }
    suite.section(fig.render());

    // harness timing: the allocator itself must stay cheap (curves are
    // memoized by now, so this times pure allocation math)
    {
        let demands: Vec<FunctionDemand> = MIXES[1]
            .1
            .iter()
            .map(|name| {
                let w = build(name, scale).expect("registry workload");
                let (curve, _) =
                    obtain_curve(store, w.as_ref(), &cfg.machine, ladder, cfg.trace.max_cached);
                FunctionDemand::new(curve)
            })
            .collect();
        let total: u64 = demands.iter().map(|d| d.curve.footprint).sum();
        let allocator = BudgetAllocator::from_config(&cfg.provision);
        suite.bench_with_throughput("allocate 3 functions", 1.0, "alloc", || {
            allocator.allocate(total / 2, &demands)
        });
    }

    let (curve_builds, curve_hits) = store.curve_counts();
    let out = Json::obj(vec![
        ("suite", Json::str("e2e_provision")),
        ("quick", Json::Bool(quick)),
        ("scale", Json::str(if quick { "small" } else { "default" })),
        ("capacity_fracs", Json::arr(CAPACITY_FRACS.iter().map(|f| Json::num(*f)))),
        ("curve_builds", Json::num(curve_builds as f64)),
        ("curve_hits", Json::num(curve_hits as f64)),
        ("series", Json::Arr(series)),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_provision.json").into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    suite.run();
}
