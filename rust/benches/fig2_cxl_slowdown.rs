//! Fig. 2 — "CXL has various latency impact to Serverless workloads."
//!
//! For every workload in the suite: execute once on the all-DRAM
//! machine with the Trace-IR recording teed off the live run, then
//! replay the stream for the pure-CXL endpoint (the workload algorithm
//! executes once per workload, not once per tier). A DRAM replay is
//! asserted field-for-field equal to the live DRAM run — the
//! replay-identity invariant, checked here at full figure scale on all
//! 13 workloads. Reports percent execution-time slowdown (sorted
//! descending, like the paper's x-axis) alongside memory
//! backend-boundness (the blue line).
//!
//! Paper shape to hold: slowdowns spread roughly 1–44%, ordered by
//! boundness; graphs / linear-equation solving / DL training at the
//! heavy end, chameleon/json/image at the light end.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench fig2_cxl_slowdown

use porter::bench::{BenchSuite, FigureReport};
use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::monitor::TopDown;
use porter::placement::static_place::replay_plain;
use porter::sim::Machine;
use porter::workloads::registry::{suite, Scale};

fn main() {
    let quick = porter::bench::quick_mode();
    let scale = if quick { Scale::Small } else { Scale::Default };
    let cfg = Config::default();
    let mut bench = BenchSuite::new("fig2: CXL slowdown across the serverless suite");

    let mut rows: Vec<(String, f64, f64, u64)> = Vec::new();
    for w in suite(scale) {
        let t0 = std::time::Instant::now();
        // live DRAM run doubles as the canonical recording
        let mut machine = Machine::all_in(&cfg.machine, TierKind::Dram);
        let mut env = porter::shim::Env::new_recording(cfg.machine.page_bytes, &mut machine);
        let checksum = w.run(&mut env);
        let mut trace = env.finish_recording().expect("recording env");
        trace.workload = w.name().to_string();
        trace.checksum = checksum;
        let dram = machine.report();
        // replay-identity at figure scale: a DRAM replay must reproduce
        // the live DRAM report exactly before we trust the CXL replay
        let dram_replay = replay_plain(&cfg, &trace, TierKind::Dram);
        assert_eq!(dram_replay, dram, "{}: replay diverged from live run", w.name());
        let cxl = replay_plain(&cfg, &trace, TierKind::Cxl);
        let slowdown = cxl.slowdown_pct_vs(&dram);
        let boundness = TopDown::from_report(&dram).offchip_bound_pct();
        eprintln!(
            "  {:12} slowdown {:6.1}%  boundness {:5.1}%  ({} accesses, host {:.1}s)",
            w.name(),
            slowdown,
            boundness,
            dram.accesses,
            t0.elapsed().as_secs_f64()
        );
        rows.push((w.name().to_string(), slowdown, boundness, dram.accesses));
    }
    rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());

    let mut fig = FigureReport::new(
        "Figure 2",
        "percent slowdown, pure CXL vs all-local-DRAM (sorted), with memory backend-boundness",
        &["slowdown_pct", "boundness_pct"],
    );
    for (name, slowdown, boundness, _) in &rows {
        fig.row(name, vec![*slowdown, *boundness]);
    }
    bench.section(fig.render());

    // Shape checks (reported, not asserted, so partial regressions are
    // still visible in output).
    let spread_ok = rows.first().map(|r| r.1 > 20.0).unwrap_or(false)
        && rows.last().map(|r| r.1 < 8.0).unwrap_or(false);
    let rank_corr = spearman(
        &rows.iter().map(|r| r.1).collect::<Vec<_>>(),
        &rows.iter().map(|r| r.2).collect::<Vec<_>>(),
    );
    bench.section(format!(
        "shape: slowdown spread {:.1}%..{:.1}% ({}), slowdown~boundness Spearman ρ={:.2} ({})\n\
         paper: 1%..44%, slowdown roughly tracks boundness",
        rows.last().map(|r| r.1).unwrap_or(0.0),
        rows.first().map(|r| r.1).unwrap_or(0.0),
        if spread_ok { "OK" } else { "NARROW" },
        rank_corr,
        if rank_corr > 0.5 { "OK" } else { "WEAK" },
    ));
    bench.run();
}

/// Spearman rank correlation.
fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let rank = |xs: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&i, &j| xs[i].partial_cmp(&xs[j]).unwrap());
        let mut r = vec![0.0; xs.len()];
        for (rank, &i) in idx.iter().enumerate() {
            r[i] = rank as f64;
        }
        r
    };
    let (ra, rb) = (rank(a), rank(b));
    let n = a.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let d2: f64 = ra.iter().zip(&rb).map(|(x, y)| (x - y) * (x - y)).sum();
    1.0 - 6.0 * d2 / (n * (n * n - 1.0))
}
