//! End-to-end fault-injection bench: the same quick fleet configuration
//! run fault-free, through a scripted node loss + rejoin, and through a
//! scripted CXL-link degradation.
//!
//! The contract under test: a fault-free `[faults]`-enabled-off run is
//! untouched (availability 1.0, zero counters), a node loss voids the
//! victim's in-flight work and retries it on survivors (availability
//! dips below 1.0 but the run completes), and a link derate degrades
//! epochs without failing anything. Every faulted cell must be
//! bit-identical across `--shards 1` and `--shards 4`. Writes
//! `BENCH_faults.json` at the repo root.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_faults

use std::time::Instant;

use porter::cluster::{simulate, ClusterReport};
use porter::config::Config;
use porter::util::json::Json;

/// Legacy-model base: the 100 ms cold start pins each node's first run
/// of every function in flight long enough that the scripted outage at
/// 100 ms is guaranteed to strand work on the victim.
fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.nodes = 2;
    cfg.cluster.functions = 2;
    cfg.cluster.rate_per_s = 800.0;
    cfg.cluster.duration_s = 0.25;
    cfg.cluster.cold_start_ns = 100_000_000;
    cfg.cluster.autoscale = false;
    cfg.cluster.seed = 0xFA_17;
    cfg
}

fn faulted_cfg(spec: &str) -> Config {
    let mut cfg = base_cfg();
    cfg.faults.enabled = true;
    cfg.faults.spec = spec.to_string();
    cfg
}

/// Run one cell, asserting shard invariance for faulted configs, and
/// return the shards=1 report plus its host time.
fn run_cell(label: &str, cfg: &Config) -> (ClusterReport, f64) {
    let t0 = Instant::now();
    let r1 = simulate(cfg).expect("cell run");
    let host_s = t0.elapsed().as_secs_f64();
    let mut sharded = cfg.clone();
    sharded.sim.shards = 4;
    let r4 = simulate(&sharded).expect("sharded cell run");
    assert_eq!(
        r1.determinism_token, r4.determinism_token,
        "{label}: token diverged across shard counts"
    );
    assert_eq!(r1, r4, "{label}: report diverged across shard counts");
    (r1, host_s)
}

fn row(label: &str, r: &ClusterReport, host_s: f64) -> Json {
    Json::obj(vec![
        ("config", Json::str(label)),
        ("completed", Json::num(r.completed as f64)),
        ("availability", Json::num(r.availability)),
        ("fault_downs", Json::num(r.fault_downs as f64)),
        ("fault_rejoins", Json::num(r.fault_rejoins as f64)),
        ("fault_degrades", Json::num(r.fault_degrades as f64)),
        ("fault_failed", Json::num(r.fault_failed as f64)),
        ("fault_retried", Json::num(r.fault_retried as f64)),
        ("degraded_epochs", Json::num(r.degraded_epochs as f64)),
        ("degraded_p99_ns", Json::num(r.degraded_p99_ns as f64)),
        ("fleet_p99_ns", Json::num(r.fleet_p99_ns as f64)),
        ("host_ms", Json::num(host_s * 1e3)),
        ("determinism_token", Json::str(format!("{:#018x}", r.determinism_token))),
    ])
}

fn main() {
    let quick = porter::bench::quick_mode();

    // cell 1 — fault-free baseline: the [faults] section off entirely
    let (clean, clean_s) = run_cell("fault-free", &base_cfg());
    assert!(!clean.faults_enabled);
    assert_eq!(clean.fault_downs + clean.fault_failed, 0);
    assert!(clean.availability == 1.0, "fault-free availability must be 1.0");

    // cell 2 — node loss at 100 ms, rejoin at 180 ms: in-flight cold
    // starts on node 1 are voided and retried on node 0
    let (loss, loss_s) = run_cell("node-loss", &faulted_cfg("down@0.1:1,up@0.18:1"));
    assert_eq!(loss.fault_downs, 1);
    assert_eq!(loss.fault_rejoins, 1);
    assert!(loss.fault_failed >= 1, "the outage must strand in-flight work");
    assert_eq!(loss.fault_retried, loss.fault_failed, "node 0 survives: all failures retry");
    assert!(
        loss.availability < 1.0 && loss.availability > 0.0,
        "node loss must dent availability, got {}",
        loss.availability
    );
    assert!(loss.degraded_epochs > 0);

    // cell 3 — both CXL links derated to 25% from 50 ms to 200 ms:
    // contention inflates but nothing fails
    let spec = "degrade@0.05:0:0.25,degrade@0.05:1:0.25,restore@0.2:0,restore@0.2:1";
    let (slow, slow_s) = run_cell("link-degrade", &faulted_cfg(spec));
    assert_eq!(slow.fault_degrades, 2);
    assert_eq!(slow.fault_failed, 0, "a slow link fails nothing");
    assert!(slow.availability == 1.0);
    assert!(slow.degraded_epochs > 0);
    assert!(slow.degraded_p99_ns > 0, "completions during the derate feed the hist");

    println!(
        "faults: clean avail {:.4} ({:.1}ms) | node-loss avail {:.4}, {} failed/{} retried \
         ({:.1}ms) | link-degrade {} degraded epochs ({:.1}ms)",
        clean.availability,
        clean_s * 1e3,
        loss.availability,
        loss.fault_failed,
        loss.fault_retried,
        loss_s * 1e3,
        slow.degraded_epochs,
        slow_s * 1e3
    );

    let out = Json::obj(vec![
        ("suite", Json::str("e2e_faults")),
        ("quick", Json::Bool(quick)),
        (
            "series",
            Json::Arr(vec![
                row("fault-free", &clean, clean_s),
                row("node-loss", &loss, loss_s),
                row("link-degrade", &slow, slow_s),
            ]),
        ),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_faults.json").into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
