//! Fig. 4 — "Heatmap of some workloads, where colored areas are denoted
//! as hot regions."
//!
//! DAMON-profiles the six workloads the paper plots (DL training,
//! Linpack, BFS, PageRank, Chameleon, image processing) and renders the
//! DAMO-style address×time heatmaps. Paper shape to hold: strong banded
//! locality for DL / Linpack / BFS / PageRank; sparse, unpredictable
//! patterns for Chameleon and image processing — quantified here by the
//! locality score (heat share of the hottest 10% of address bins).
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench fig4_heatmaps

use porter::bench::{BenchSuite, FigureReport};
use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::monitor::{Damon, Heatmap};
use porter::sim::Machine;
use porter::trace::record_workload;
use porter::workloads::registry::{build, Scale};

const WORKLOADS: [&str; 6] = ["dl_train", "linpack", "bfs", "pagerank", "chameleon", "image"];

/// Record the workload's Trace-IR once, then replay it through a
/// DAMON-observed CXL machine — the record-once/replay-many shape of
/// the paper's own profile phase.
fn profile(name: &str, scale: Scale, cfg: &Config) -> (Heatmap, u64) {
    let w = build(name, scale).expect("workload");
    let trace = record_workload(w.as_ref(), cfg.machine.page_bytes);
    let mut machine = Machine::all_in(&cfg.machine, TierKind::Cxl);
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine.attach_observer(Box::new(Damon::new(&cfg.monitor, cfg.machine.page_bytes, 0xF16)));
    machine.replay(&trace);
    let damon =
        machine.take_observers().pop().unwrap().into_any().downcast::<Damon>().unwrap();
    let lo = trace
        .objects
        .iter()
        .filter(|o| o.via_mmap)
        .map(|o| o.start)
        .min()
        .unwrap_or(porter::shim::intercept::MMAP_BASE);
    let hi =
        trace.objects.iter().filter(|o| o.via_mmap).map(|o| o.end()).max().unwrap_or(lo + 1);
    let map = Heatmap::from_damon(&damon.snapshots, lo, hi, 72, 20);
    (map, damon.samples_taken)
}

fn main() {
    let quick = porter::bench::quick_mode();
    let scale = if quick { Scale::Small } else { Scale::Default };
    let cfg = Config::default();
    let mut bench = BenchSuite::new("fig4: DAMON access heatmaps");

    let mut fig = FigureReport::new(
        "Figure 4",
        "locality score per workload (share of heat in hottest 10% of address bins)",
        &["locality_score", "damon_samples"],
    );
    let mut scores = Vec::new();
    for name in WORKLOADS {
        let (map, samples) = profile(name, scale, &cfg);
        let score = map.locality_score();
        let ascii = map.render_ascii();
        bench.section(format!("--- {name} ---\n{ascii}locality score: {score:.2}\n"));
        fig.row(name, vec![score, samples as f64]);
        scores.push((name, score));
    }
    bench.section(fig.render());

    let strong: f64 = scores
        .iter()
        .filter(|(n, _)| ["dl_train", "linpack", "bfs", "pagerank"].contains(n))
        .map(|(_, s)| *s)
        .sum::<f64>()
        / 4.0;
    let sparse: f64 = scores
        .iter()
        .filter(|(n, _)| ["chameleon", "image"].contains(n))
        .map(|(_, s)| *s)
        .sum::<f64>()
        / 2.0;
    bench.section(format!(
        "shape: mean locality strong-class {strong:.2} vs sparse-class {sparse:.2} ({})\n\
         paper: DL/Linpack/BFS/PageRank show strong locality; Chameleon/image are sparse",
        if strong > sparse { "OK" } else { "INVERTED" }
    ));
    bench.run();
}
