//! End-to-end Trace-IR bench: record-once-replay-N vs execute-N.
//!
//! The tentpole claim of the trace layer is that a sweep of N cells
//! (policy × DRAM-ratio × config) needs one live workload execution,
//! not N: every cell replays the stored stream, and the replay-identity
//! invariant guarantees the replayed cells report exactly what live
//! cells would have. This bench measures both arms on the same cells,
//! asserts the reports are field-for-field identical, asserts the reuse
//! counter (live executions saved) is strictly positive, and times the
//! host-side cost of each arm. The transform section exercises
//! `truncated` (quick-mode prefixes), `scaled` (N warm invocations),
//! and `interleave` (colocated tenants merged into one stream).
//!
//! Writes the series to `BENCH_trace.json` at the repo root so future
//! PRs have a replay-speedup trajectory to compare against.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_trace

use porter::bench::{fmt_ns, BenchConfig, BenchSuite, FigureReport};
use porter::config::Config;
use porter::mem::migrate::MigrationEngine;
use porter::mem::tier::TierKind;
use porter::placement::policies::FirstTouchDram;
use porter::sim::machine::RunReport;
use porter::sim::Machine;
use porter::trace::{interleave, record_workload};
use porter::util::json::Json;
use porter::workloads::registry::{build, Scale};

const WORKLOADS: [&str; 3] = ["pagerank", "kvstore", "dl_serve"];
const POLICIES: [&str; 2] = ["none", "tpp"];
const DRAM_RATIOS: [f64; 2] = [0.25, 0.5];

/// Build one sweep-cell machine: DRAM capped at `ratio` × footprint,
/// first-touch placement, the configured migration engine attached.
fn cell_machine(cfg: &Config, footprint: u64, ratio: f64, policy: &str) -> Machine {
    let mut mcfg = cfg.machine.clone();
    let footprint = footprint.max(mcfg.page_bytes);
    mcfg.dram_bytes =
        ((footprint as f64 * ratio) as u64 / mcfg.page_bytes).max(4) * mcfg.page_bytes;
    let mut machine = Machine::new(&mcfg, Box::new(FirstTouchDram::default()));
    let mut migration = cfg.migration.clone();
    migration.policy = policy.to_string();
    migration.enabled = policy != "none";
    if let Some(engine) = MigrationEngine::from_config(&migration) {
        machine.set_migrator(Box::new(engine));
    }
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine
}

fn main() {
    let quick = porter::bench::quick_mode();
    let scale = if quick { Scale::Small } else { Scale::Default };
    let cfg = Config::default();
    let mut suite = BenchSuite::new("e2e: Trace-IR record-once-replay-N vs execute-N")
        .with_config(BenchConfig {
            warmup_iters: 1,
            sample_iters: 3,
            max_time: std::time::Duration::from_secs(60),
        });

    let cells: Vec<(f64, &str)> = DRAM_RATIOS
        .iter()
        .flat_map(|&r| POLICIES.iter().map(move |&p| (r, p)))
        .collect();

    let mut fig = FigureReport::new(
        "trace-replay-speedup",
        "host time per sweep: execute every cell vs record once + replay",
        &["speedup_x", "execute_ms", "record_ms", "replay_ms", "reuse"],
    );
    let mut series = Vec::new();
    for name in WORKLOADS {
        let w = build(name, scale).expect("registry workload");
        let footprint = w.footprint_hint();

        // ---- arm A: execute every cell live ----
        let t0 = std::time::Instant::now();
        let mut live_reports: Vec<RunReport> = Vec::new();
        for &(ratio, policy) in &cells {
            let mut machine = cell_machine(&cfg, footprint, ratio, policy);
            let mut env = porter::shim::Env::new(cfg.machine.page_bytes, &mut machine);
            std::hint::black_box(w.run(&mut env));
            drop(env);
            live_reports.push(machine.report());
        }
        let execute_ns = t0.elapsed().as_nanos() as f64;

        // ---- arm B: record once, replay every cell ----
        let t0 = std::time::Instant::now();
        let trace = record_workload(w.as_ref(), cfg.machine.page_bytes);
        let record_ns = t0.elapsed().as_nanos() as f64;
        let t0 = std::time::Instant::now();
        let mut replay_reports: Vec<RunReport> = Vec::new();
        for &(ratio, policy) in &cells {
            let mut machine = cell_machine(&cfg, footprint, ratio, policy);
            machine.replay(&trace);
            replay_reports.push(machine.report());
        }
        let replay_ns = t0.elapsed().as_nanos() as f64;

        // ---- the invariant and the reuse counter ----
        for (i, (live, replayed)) in live_reports.iter().zip(&replay_reports).enumerate() {
            assert_eq!(
                replayed, live,
                "{name} cell {i} ({:?}): replayed report diverged from live",
                cells[i]
            );
        }
        let live_execs_execute = cells.len() as u64;
        let live_execs_replay = 1u64; // the recording
        let reuse = live_execs_execute - live_execs_replay;
        assert!(
            reuse > 0,
            "{name}: replayed cells must pay strictly fewer live executions than cells"
        );
        let speedup = execute_ns / (record_ns + replay_ns).max(1.0);
        eprintln!(
            "  {name:9} {} cells: execute {} vs record {} + replay {} ({speedup:.2}x, \
             reuse {reuse})",
            cells.len(),
            fmt_ns(execute_ns),
            fmt_ns(record_ns),
            fmt_ns(replay_ns)
        );
        fig.row(
            name,
            vec![speedup, execute_ns / 1e6, record_ns / 1e6, replay_ns / 1e6, reuse as f64],
        );
        series.push(Json::obj(vec![
            ("workload", Json::str(name)),
            ("cells", Json::num(cells.len() as f64)),
            ("live_execs_execute", Json::num(live_execs_execute as f64)),
            ("live_execs_replay", Json::num(live_execs_replay as f64)),
            ("reuse", Json::num(reuse as f64)),
            ("execute_host_ns", Json::num(execute_ns)),
            ("record_host_ns", Json::num(record_ns)),
            ("replay_host_ns", Json::num(replay_ns)),
            ("speedup_x", Json::num(speedup)),
            ("events", Json::num(trace.len() as f64)),
            ("trace_bytes", Json::num(trace.encoded_bytes() as f64)),
            ("wall_ns", Json::num(replay_reports[0].wall_ns)),
        ]));
        eprintln!("TRACE-REUSE workload={name} cells={} live_execs=1 reuse={reuse}", cells.len());
    }
    suite.section(fig.render());

    // ---- transforms: derive new streams without re-executing ----
    let a = record_workload(build("kvstore", Scale::Small).unwrap().as_ref(), 4096);
    let b = record_workload(build("json", Scale::Small).unwrap().as_ref(), 4096);
    // truncate: quick-mode prefix
    let cut = a.truncated(a.len() / 2);
    let cut_report = {
        let mut m = Machine::all_in(&cfg.machine, TierKind::Dram);
        m.replay(&cut);
        m.report()
    };
    // scale: three warm invocations back-to-back
    let tripled = a.scaled(3);
    assert_eq!(tripled.n_accesses(), a.n_accesses() * 3);
    // interleave: two tenants merged into one relocated stream
    let merged = interleave(&[&a, &b], 256, cfg.machine.page_bytes);
    assert_eq!(merged.n_accesses(), a.n_accesses() + b.n_accesses());
    let merged_report = {
        let mut m = Machine::all_in(&cfg.machine, TierKind::Cxl);
        m.replay(&merged);
        m.report()
    };
    suite.section(format!(
        "transforms: truncate(1/2) replayed {} events in {}, scale(3) = {} accesses, \
         interleave(kvstore+json) = {} accesses in {}",
        cut.len(),
        fmt_ns(cut_report.wall_ns),
        tripled.n_accesses(),
        merged_report.accesses,
        fmt_ns(merged_report.wall_ns)
    ));

    // ---- host-side timing of one replay cell ----
    let trace = record_workload(build("kvstore", Scale::Small).unwrap().as_ref(), 4096);
    suite.bench_with_throughput("replay_kvstore_small_dram", trace.len() as f64, "event", || {
        let mut m = Machine::all_in(&cfg.machine, TierKind::Dram);
        m.replay(&trace);
        m.report().accesses
    });

    // ---- persist the series for future PRs ----
    let out = Json::obj(vec![
        ("suite", Json::str("e2e_trace")),
        ("quick", Json::Bool(quick)),
        ("scale", Json::str(if quick { "small" } else { "default" })),
        ("policies", Json::arr(POLICIES.iter().map(|p| Json::str(*p)))),
        ("dram_ratios", Json::arr(DRAM_RATIOS.iter().map(|r| Json::num(*r)))),
        ("series", Json::Arr(series)),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_trace.json").into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    suite.run();
}
