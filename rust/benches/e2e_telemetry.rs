//! End-to-end telemetry overhead bench: the same quick fleet
//! configuration with `[telemetry]` off and on, host-timed.
//!
//! Telemetry's contract is "free when off, cheap when on": the off arm
//! must be bit-identical to a main-branch run (checked via the
//! determinism token against the on arm, which must match too), and
//! the on arm's host-time overhead must stay under 10% on the quick
//! configuration. Writes `BENCH_telemetry.json` at the repo root so
//! future PRs can track the overhead trajectory.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_telemetry

use std::time::Instant;

use porter::cluster::simulate_full;
use porter::config::Config;
use porter::util::json::Json;

fn cfg(telemetry: bool) -> Config {
    let mut cfg = Config::default();
    cfg.cluster.nodes = 2;
    cfg.cluster.functions = 4;
    cfg.cluster.rate_per_s = 400.0;
    cfg.cluster.duration_s = 0.25;
    cfg.cluster.autoscale = false;
    cfg.cluster.seed = 0x7E1E;
    cfg.lifecycle.enabled = true;
    cfg.lifecycle.warm_pool_bytes = 256 * 1024 * 1024;
    cfg.lifecycle.snapshot = true;
    cfg.telemetry.enabled = telemetry;
    cfg.telemetry.epoch_ns = 10_000_000;
    cfg
}

fn main() {
    let quick = porter::bench::quick_mode();
    let iters = if quick { 3 } else { 5 };

    // warmup both arms once — this also populates the process-wide
    // Trace-IR memo, so the timed runs below replay identical work
    let (base, off_tele) = simulate_full(&cfg(false)).expect("off-arm run");
    let (inst, tele) = simulate_full(&cfg(true)).expect("on-arm run");
    assert!(!off_tele.is_enabled() && off_tele.sink.total_events() == 0);
    assert_eq!(
        base.determinism_token, inst.determinism_token,
        "telemetry must not perturb the simulation"
    );
    assert_eq!(base.fleet_p99_ns, inst.fleet_p99_ns);
    let kinds = tele.sink.kind_counts();
    assert!(kinds.len() >= 4, "expected >= 4 event kinds, got {kinds:?}");
    assert!(tele.series.len() >= 5, "expected >= 5 series, got {}", tele.series.len());
    let doc = tele.to_chrome_json(vec![]);
    let parsed = Json::parse(&doc.to_string_compact()).expect("chrome JSON parses back");
    assert!(!parsed.get("traceEvents").unwrap().as_arr().unwrap().is_empty());
    eprintln!(
        "collected {} events ({} dropped), {} series — {:?}",
        tele.sink.total_events(),
        tele.sink.dropped_events(),
        tele.series.len(),
        kinds
    );

    // min-of-N host timing per arm: robust against scheduler noise
    let time_arm = |telemetry: bool| -> f64 {
        let c = cfg(telemetry);
        let mut best = f64::INFINITY;
        for _ in 0..iters {
            let t0 = Instant::now();
            let (r, t) = simulate_full(&c).expect("timed run");
            assert_eq!(r.determinism_token, base.determinism_token);
            std::hint::black_box(t);
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    let off_s = time_arm(false);
    let on_s = time_arm(true);
    let overhead_frac = (on_s - off_s) / off_s;
    assert!(overhead_frac.is_finite(), "overhead must be measurable");
    assert!(
        overhead_frac < 0.10,
        "telemetry overhead {:.2}% exceeds the 10% budget (off {:.1}ms on {:.1}ms)",
        overhead_frac * 100.0,
        off_s * 1e3,
        on_s * 1e3
    );
    println!(
        "telemetry overhead: off {:.2}ms / on {:.2}ms → {:+.2}% (budget 10%)",
        off_s * 1e3,
        on_s * 1e3,
        overhead_frac * 100.0
    );

    let out = Json::obj(vec![
        ("suite", Json::str("e2e_telemetry")),
        ("quick", Json::Bool(quick)),
        (
            "series",
            Json::Arr(vec![Json::obj(vec![
                ("config", Json::str("cluster-quick-2n")),
                ("off_host_ms", Json::num(off_s * 1e3)),
                ("on_host_ms", Json::num(on_s * 1e3)),
                ("overhead_frac", Json::num(overhead_frac)),
                ("events", Json::num(tele.sink.total_events() as f64)),
                ("dropped_events", Json::num(tele.sink.dropped_events() as f64)),
                ("series_count", Json::num(tele.series.len() as f64)),
                (
                    "determinism_token",
                    Json::str(format!("{:#018x}", inst.determinism_token)),
                ),
            ])]),
        ),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON").unwrap_or_else(|_| {
        concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_telemetry.json").into()
    });
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
