//! End-to-end Porter serving bench (Fig. 6 control path + Table 1
//! testbed): a mixed function population invoked through gateway →
//! balancer → engine, measuring host-side orchestration throughput,
//! hint-cache effectiveness, SLO outcomes, and — when `make artifacts`
//! has run — real PJRT DL inference latency on the same path.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_serving

use std::sync::Arc;

use porter::bench::BenchSuite;
use porter::config::Config;
use porter::metrics::Histogram;
use porter::porter::slo::SloTracker;
use porter::porter::{FunctionSpec, Gateway};
use porter::util::table::Table;
use porter::workloads::registry::{build, Scale};

fn main() {
    let quick = porter::bench::quick_mode();
    let rounds = if quick { 3 } else { 12 };
    let mut cfg = Config::default();
    cfg.porter.servers = 2;
    cfg.porter.workers_per_server = 4;
    let mut bench = BenchSuite::new("e2e: Porter serving a mixed function population");

    let functions = ["kvstore", "json", "chameleon", "compression", "image", "dl_serve"];
    let mut gw = Gateway::new(&cfg);
    for f in functions {
        gw.deploy(FunctionSpec::new(f, Arc::from(build(f, Scale::Small).unwrap())));
    }

    let mut slo = SloTracker::default();
    let lat = Histogram::default();
    let mut hint_hits = 0u64;
    let mut total = 0u64;
    let t0 = std::time::Instant::now();
    // first wave profiles every function; wait for hints once
    for (i, f) in functions.iter().enumerate() {
        let out = gw.invoke(f).unwrap().wait();
        slo.record(&out);
        total += 1;
        std::hint::black_box(i);
    }
    gw.tuner.drain();
    for _round in 0..rounds {
        let tickets: Vec<_> = functions.iter().map(|f| gw.invoke(f).unwrap()).collect();
        for t in tickets {
            let out = t.wait();
            lat.record(out.host_micros * 1000);
            if out.used_hint {
                hint_hits += 1;
            }
            slo.record(&out);
            total += 1;
        }
    }
    let secs = t0.elapsed().as_secs_f64();
    let mut t = Table::new(&["metric", "value"]).left_first();
    t.row(vec!["invocations".into(), total.to_string()]);
    t.row(vec!["host throughput".into(), format!("{:.1} inv/s", total as f64 / secs)]);
    t.row(vec![
        "engine latency (host)".into(),
        format!(
            "mean {} p99≤{}",
            porter::bench::fmt_ns(lat.mean()),
            porter::bench::fmt_ns(lat.percentile(99.0) as f64)
        ),
    ]);
    t.row(vec![
        "hint hit rate (post-warmup)".into(),
        format!("{:.1}%", 100.0 * hint_hits as f64 / (total - functions.len() as u64) as f64),
    ]);
    t.row(vec![
        "SLO violation rate".into(),
        format!("{:.1}%", slo.overall_violation_rate() * 100.0),
    ]);
    bench.section(t.render());
    gw.shutdown();

    // record-once/replay-many on the serving path: only the fleet-wide
    // first invocation of each (function, size) executed its body; all
    // repeats replayed the stored Trace-IR
    let (records, replays, bytes) = porter::trace::TraceStore::global().counts();
    bench.section(format!(
        "trace IR: {records} recorded ({}), {replays} replays — \
         {total} invocations paid {records} live workload executions",
        porter::util::bytes::fmt_bytes(bytes)
    ));

    // PJRT inference on the same path, if artifacts exist.
    let artifact_dir = porter::runtime::ArtifactManifest::default_dir();
    if let Ok(rt) = porter::runtime::ModelRuntime::load(artifact_dir) {
        let params = porter::runtime::MlpParams::init(&rt.manifest.model_layers.clone(), 3);
        let sig = rt.manifest.get("mlp_infer").unwrap();
        let xin = sig.inputs.last().unwrap().clone();
        let x: Vec<f32> = (0..xin.elements()).map(|i| (i % 17) as f32 * 0.05).collect();
        bench.bench_with_throughput("pjrt_mlp_infer_batch8", 8.0, "req", || {
            rt.mlp_infer(&params, &x).unwrap()
        });
        if rt.has("mlp_infer_fused") {
            bench.bench_with_throughput("pjrt_mlp_infer_fused_batch8", 8.0, "req", || {
                rt.mlp_infer_with("mlp_infer_fused", &params, &x).unwrap()
            });
        }
        let msig = rt.manifest.get("matmul").unwrap();
        let n = msig.inputs[0].shape[0];
        let a: Vec<f32> = (0..n * n).map(|i| (i % 7) as f32).collect();
        bench.bench_with_throughput(
            "pjrt_pallas_matmul_256",
            2.0 * (n as f64).powi(3),
            "flop",
            || rt.matmul(&a, &a).unwrap(),
        );
    } else {
        bench.section("artifacts/ missing — run `make artifacts` for the PJRT benches".into());
    }
    bench.run();
}
