//! End-to-end fleet bench: open-loop arrivals over 1→16 nodes × three
//! arrival shapes, with the shared CXL pool contended throughout.
//!
//! The offered load is calibrated against single-node capacity (2× —
//! an overloaded single node) so the sweep shows real queueing relief
//! as nodes are added. Reports virtual-time p50/p99 e2e latency, queue
//! wait, cost proxy, and the determinism token per configuration, and
//! writes the whole series to `BENCH_cluster.json` at the repo root so
//! future PRs have a perf trajectory to compare against.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_cluster

use porter::bench::{fmt_ns, BenchConfig, BenchSuite, FigureReport};
use porter::cluster::simulate;
use porter::config::Config;
use porter::util::json::Json;

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.functions = 6;
    cfg.cluster.zipf_theta = 0.9;
    cfg.cluster.seed = 0xC1;
    cfg.cluster.autoscale = false;
    cfg.cluster.workers_per_server = 4;
    cfg.cluster.min_nodes = 1;
    cfg.cluster.max_nodes = 32;
    cfg
}

fn main() {
    let quick = porter::bench::quick_mode();
    let node_counts: &[usize] = if quick { &[1, 2, 4] } else { &[1, 2, 4, 8, 16] };
    let shapes = ["poisson", "bursty", "diurnal"];
    let duration_s = if quick { 0.25 } else { 0.5 };

    // each sample is a full fleet run (with real measurement executions
    // inside), so keep the host-timing sample count small
    let mut suite = BenchSuite::new("e2e: fleet simulation (cluster/) — nodes × arrival shapes")
        .with_config(BenchConfig {
            warmup_iters: 1,
            sample_iters: 3,
            max_time: std::time::Duration::from_secs(60),
        });

    // ---- calibrate offered load to 2.5× single-node capacity ----
    // enough calibration arrivals that warm (hinted) service dominates
    // the mean, not the handful of profile runs
    let mut cal = base_cfg();
    cal.cluster.nodes = 1;
    cal.cluster.rate_per_s = 500.0;
    cal.cluster.duration_s = 0.2;
    let cal_report = simulate(&cal).expect("calibration run");
    let mean_service_s = (cal_report.mean_service_ns / 1e9).max(1e-6);
    let single_node_capacity =
        cal.cluster.servers_per_node as f64 * cal.cluster.workers_per_server as f64
            / mean_service_s;
    let rate = 2.5 * single_node_capacity;
    suite.section(format!(
        "calibration: mean service {} → 1-node capacity {:.0} inv/s → offered load {:.0} inv/s",
        fmt_ns(cal_report.mean_service_ns),
        single_node_capacity,
        rate
    ));

    // ---- the sweep ----
    let mut fig = FigureReport::new(
        "fleet-scaling",
        "e2e p99 vs node count under 2.5× single-node load",
        &["p99_ms", "p50_ms", "mean_wait_ms", "throughput_per_s", "cost_units"],
    );
    let mut series = Vec::new();
    for shape in shapes {
        for &n in node_counts {
            let mut cfg = base_cfg();
            cfg.cluster.nodes = n;
            cfg.cluster.arrivals = shape.to_string();
            cfg.cluster.rate_per_s = rate;
            cfg.cluster.duration_s = duration_s;
            let r = simulate(&cfg).expect("fleet run");
            fig.row(
                &format!("{shape}/{n}n"),
                vec![
                    r.fleet_p99_ns as f64 / 1e6,
                    r.fleet_p50_ns as f64 / 1e6,
                    r.mean_wait_ns / 1e6,
                    r.throughput_per_s,
                    r.cost_units,
                ],
            );
            series.push(Json::obj(vec![
                ("shape", Json::str(shape)),
                ("nodes", Json::num(n as f64)),
                ("completed", Json::num(r.completed as f64)),
                ("p50_ns", Json::num(r.fleet_p50_ns as f64)),
                ("p99_ns", Json::num(r.fleet_p99_ns as f64)),
                ("mean_ns", Json::num(r.fleet_mean_ns)),
                ("mean_wait_ns", Json::num(r.mean_wait_ns)),
                ("mean_service_ns", Json::num(r.mean_service_ns)),
                ("throughput_per_s", Json::num(r.throughput_per_s)),
                ("violation_rate", Json::num(r.violation_rate)),
                ("pool_peak_occupancy", Json::num(r.pool_peak_occupancy)),
                ("node_seconds", Json::num(r.node_seconds)),
                ("cost_units", Json::num(r.cost_units)),
                ("events_per_sec", Json::num(r.shards.events_per_sec)),
                ("determinism_token", Json::str(format!("{:#018x}", r.determinism_token))),
            ]));
            eprintln!(
                "  {shape}/{n}n: p99 {} wait {} cost {:.1}",
                fmt_ns(r.fleet_p99_ns as f64),
                fmt_ns(r.mean_wait_ns),
                r.cost_units
            );
        }
    }
    suite.section(fig.render());

    // ---- determinism + scaling checks ----
    let mut check = base_cfg();
    check.cluster.nodes = 2;
    check.cluster.rate_per_s = rate;
    check.cluster.duration_s = duration_s.min(0.25);
    let a = simulate(&check).expect("determinism run A");
    let b = simulate(&check).expect("determinism run B");
    assert_eq!(
        a.determinism_token, b.determinism_token,
        "fleet run must be deterministic under a fixed seed"
    );
    suite.section(format!(
        "determinism: token {:#018x} reproduced across two runs",
        a.determinism_token
    ));
    // sharded execution must reproduce the single-thread run bit for
    // bit — and its host-side event rate is the simulator-speed number
    // the SHARDS counter line tracks
    let mut sharded = check.clone();
    sharded.sim.shards = 4;
    let s = simulate(&sharded).expect("sharded determinism run");
    assert_eq!(
        a.determinism_token, s.determinism_token,
        "--shards 4 must reproduce the single-thread determinism token"
    );
    assert_eq!(a, s, "--shards 4 must reproduce the whole report");
    suite.section(format!(
        "sharding: 4-shard run matches 1-shard ({:.0} vs {:.0} events/s host-side)",
        s.shards.events_per_sec, a.shards.events_per_sec
    ));
    let mean_wait = |nodes: usize| -> f64 {
        let mut cfg = base_cfg();
        cfg.cluster.nodes = nodes;
        cfg.cluster.rate_per_s = rate;
        cfg.cluster.duration_s = duration_s.min(0.25);
        let r = simulate(&cfg).expect("scaling run");
        r.mean_wait_ns
    };
    let (w1, w4) = (mean_wait(1), mean_wait(4));
    assert!(
        w4 <= w1 * 1.05 + 10_000.0,
        "4 nodes must not queue worse than 1 under the same load: {w4} vs {w1}"
    );
    suite.section(format!(
        "scaling: mean wait {} (1 node) → {} (4 nodes) under 2.5× single-node load",
        fmt_ns(w1),
        fmt_ns(w4)
    ));

    // ---- autoscaler demo: start at min, let the signals grow it ----
    let mut auto_cfg = base_cfg();
    auto_cfg.cluster.nodes = 1;
    auto_cfg.cluster.max_nodes = 8;
    auto_cfg.cluster.autoscale = true;
    auto_cfg.cluster.rate_per_s = rate;
    auto_cfg.cluster.duration_s = duration_s;
    let auto_report = simulate(&auto_cfg).expect("autoscale run");
    suite.section(format!(
        "autoscaler: {} events under 2× load starting from 1 node (final wait {})\n{}",
        auto_report.events.len(),
        fmt_ns(auto_report.mean_wait_ns),
        auto_report
            .events
            .iter()
            .map(|e| format!(
                "  t={:6.3}s {} → {} nodes ({})",
                e.t_ns as f64 / 1e9,
                e.direction.name(),
                e.nodes_after,
                e.reason
            ))
            .collect::<Vec<_>>()
            .join("\n")
    ));

    // ---- host-side timing of one mid-size configuration ----
    let mut host_cfg = base_cfg();
    host_cfg.cluster.nodes = 8;
    host_cfg.cluster.rate_per_s = rate;
    host_cfg.cluster.duration_s = 0.2;
    let arrivals = rate * 0.2;
    suite.bench_with_throughput("simulate_8n_poisson", arrivals, "arrival", || {
        simulate(&host_cfg).unwrap()
    });

    // ---- persist the series for future PRs ----
    let out = Json::obj(vec![
        ("suite", Json::str("e2e_cluster")),
        ("quick", Json::Bool(quick)),
        ("offered_rate_per_s", Json::num(rate)),
        ("duration_s", Json::num(duration_s)),
        ("calibration_mean_service_ns", Json::num(cal_report.mean_service_ns)),
        ("autoscaler_events", Json::num(auto_report.events.len() as f64)),
        ("series", Json::Arr(series)),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_cluster.json").into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    suite.run();
}
