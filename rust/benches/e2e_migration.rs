//! Migration policy sweep: the three `mem::migrate` policies (naive /
//! tpp / hybrid) × three workloads (dl_train, pagerank, kvstore) ×
//! DRAM:CXL capacity ratios, against the no-migration and all-DRAM
//! endpoints.
//!
//! Setup per cell: a machine whose DRAM is a fraction of the workload's
//! footprint (first-touch placement spills the rest to CXL), the epoch
//! engine ticked at the aggregation interval. Reported per cell:
//! slowdown vs the all-DRAM endpoint, promotions/demotions, ping-pongs,
//! and migration traffic. The whole series lands in
//! `BENCH_migration.json` at the repo root so policy regressions are
//! diffable across PRs.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_migration

use porter::bench::{fmt_ns, BenchSuite, FigureReport};
use porter::config::Config;
use porter::mem::migrate::MigrationEngine;
use porter::mem::tier::TierKind;
use porter::placement::policies::FirstTouchDram;
use porter::placement::static_place::replay_plain;
use porter::sim::machine::RunReport;
use porter::sim::Machine;
use porter::trace::{record_workload, AccessTrace};
use porter::util::json::Json;
use porter::workloads::registry::{build, Scale};

const POLICIES: [&str; 4] = ["none", "naive", "tpp", "hybrid"];
const WORKLOADS: [&str; 3] = ["dl_train", "pagerank", "kvstore"];
const DRAM_RATIOS: [f64; 3] = [0.125, 0.25, 0.5];

/// One cell: DRAM capped at `ratio` × footprint, first-touch placement,
/// the configured migration engine attached, the workload's Trace-IR
/// replayed (the workload itself executed exactly once, at record
/// time).
fn run_cell(
    trace: &AccessTrace,
    footprint: u64,
    cfg: &Config,
    ratio: f64,
    policy: &str,
) -> RunReport {
    let mut mcfg = cfg.machine.clone();
    let footprint = footprint.max(mcfg.page_bytes);
    mcfg.dram_bytes =
        ((footprint as f64 * ratio) as u64 / mcfg.page_bytes).max(4) * mcfg.page_bytes;
    let mut machine = Machine::new(&mcfg, Box::new(FirstTouchDram::default()));
    let mut migration = cfg.migration.clone();
    migration.policy = policy.to_string();
    migration.enabled = policy != "none";
    if let Some(engine) = MigrationEngine::from_config(&migration) {
        machine.set_migrator(Box::new(engine));
    }
    machine.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
    machine.replay(trace);
    machine.report()
}

fn main() {
    let quick = porter::bench::quick_mode();
    let scale = if quick { Scale::Small } else { Scale::Default };
    let cfg = Config::default();
    let mut suite = BenchSuite::new("e2e: migration policy sweep (mem/migrate/)");

    let mut fig = FigureReport::new(
        "migration-sweep",
        "slowdown vs all-DRAM (%) per (workload, DRAM ratio, policy)",
        &["slowdown_pct", "promotions", "demotions", "ping_pongs", "migration_mib"],
    );
    let mut series = Vec::new();
    for name in WORKLOADS {
        let w = build(name, scale).expect("registry workload");
        // record once; the 13 cells below (1 endpoint + 3 ratios × 4
        // policies) all replay this stream
        let trace = record_workload(w.as_ref(), cfg.machine.page_bytes);
        let footprint = w.footprint_hint();
        // all-DRAM endpoint for the slowdown baseline
        let base = replay_plain(&cfg, &trace, TierKind::Dram);
        for &ratio in &DRAM_RATIOS {
            let mut outcomes: Vec<(String, RunReport)> = Vec::new();
            for policy in POLICIES {
                let t0 = std::time::Instant::now();
                let r = run_cell(&trace, footprint, &cfg, ratio, policy);
                eprintln!(
                    "  {name}/{ratio}/{policy}: wall {} (+{:.1}%) {}↑ {}↓ (host {:.1}s)",
                    fmt_ns(r.wall_ns),
                    r.slowdown_pct_vs(&base),
                    r.promotions,
                    r.demotions,
                    t0.elapsed().as_secs_f64()
                );
                outcomes.push((policy.to_string(), r));
            }
            for (policy, r) in &outcomes {
                fig.row(
                    &format!("{name}/dram={ratio}/{policy}"),
                    vec![
                        r.slowdown_pct_vs(&base),
                        r.promotions as f64,
                        r.demotions as f64,
                        r.ping_pongs as f64,
                        r.migration_bytes as f64 / (1 << 20) as f64,
                    ],
                );
                series.push(Json::obj(vec![
                    ("workload", Json::str(name)),
                    ("dram_ratio", Json::num(ratio)),
                    ("policy", Json::str(policy.clone())),
                    ("wall_ns", Json::num(r.wall_ns)),
                    ("slowdown_vs_dram_pct", Json::num(r.slowdown_pct_vs(&base))),
                    ("promotions", Json::num(r.promotions as f64)),
                    ("demotions", Json::num(r.demotions as f64)),
                    ("ping_pongs", Json::num(r.ping_pongs as f64)),
                    ("migration_bytes", Json::num(r.migration_bytes as f64)),
                    ("migration_stall_ns", Json::num(r.migration_stall_ns)),
                    ("peak_dram_bytes", Json::num(r.peak_dram_bytes as f64)),
                    ("cxl_miss_frac", {
                        let misses = (r.dram_misses + r.cxl_misses).max(1);
                        Json::num(r.cxl_misses as f64 / misses as f64)
                    }),
                ]));
            }
            // the sweep's reason to exist: policies must differ
            let distinct = {
                let sig = |r: &RunReport| (r.promotions, r.demotions, r.wall_ns.round() as u64);
                let mut sigs: Vec<_> = outcomes.iter().map(|(_, r)| sig(r)).collect();
                sigs.sort_unstable();
                sigs.dedup();
                sigs.len()
            };
            if distinct <= 1 {
                eprintln!("  NOTE {name}/dram={ratio}: all policies identical (no tier pressure)");
            }
        }
    }
    suite.section(fig.render());

    let out = Json::obj(vec![
        ("suite", Json::str("e2e_migration")),
        ("quick", Json::Bool(quick)),
        ("scale", Json::str(if quick { "small" } else { "default" })),
        ("policies", Json::arr(POLICIES.iter().map(|p| Json::str(*p)))),
        ("dram_ratios", Json::arr(DRAM_RATIOS.iter().map(|r| Json::num(*r)))),
        ("series", Json::Arr(series)),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_migration.json").into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    suite.run();
}
