//! Ablations over the design choices DESIGN.md §6 calls out:
//!
//! 1. DRAM-budget sweep — how much near-tier memory does hinted
//!    placement need before the CXL penalty is gone?
//! 2. Hot-threshold sweep — hint classifier sensitivity.
//! 3. DAMON sampling-interval sweep — profile fidelity vs overhead
//!    (samples taken), and the resulting hint quality.
//! 4. Policy shoot-out — all-DRAM / all-CXL / first-touch / static-hint
//!    / TPP-like reactive migration on the same workload.
//!
//! Record-once/replay-many: the PageRank instance executes exactly once
//! (the Trace-IR recording); all 20+ sweep cells replay the stored
//! stream, so the sweep is O(cells × replay) instead of
//! O(cells × live-execution).
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench ablations

use porter::bench::{BenchSuite, FigureReport};
use porter::config::Config;
use porter::mem::tier::TierKind;
use porter::placement::policies::{FirstTouchDram, TppMigrator};
use porter::placement::static_place::{profile_and_place_trace, replay_plain};
use porter::sim::Machine;
use porter::trace::record_workload;
use porter::workloads::graph::rmat;
use porter::workloads::pagerank::PageRank;
use porter::workloads::registry::GRAPH_SEED;
use porter::workloads::Workload;

/// Mid-sized pagerank: big enough that tiers matter (contrib > LLC),
/// small enough to sweep many configurations.
fn workload(quick: bool) -> PageRank {
    let scale = if quick { 15 } else { 18 };
    PageRank::new(rmat(scale, 6, GRAPH_SEED), 2)
}

fn main() {
    let quick = porter::bench::quick_mode();
    let w = workload(quick);
    let mut bench = BenchSuite::new("ablations: hint generation + placement policies");

    // the single live execution of the sweep
    let trace = record_workload(&w, Config::default().machine.page_bytes);

    // --- 1. DRAM budget sweep ---
    let mut fig = FigureReport::new(
        "Ablation 1",
        "hinted slowdown vs all-DRAM (%), as the DRAM budget fraction grows",
        &["hinted_slowdown_pct", "improvement_over_cxl_pct"],
    );
    for budget in [0.0, 0.1, 0.2, 0.35, 0.5, 0.75, 1.0] {
        let mut cfg = Config::default();
        cfg.porter.dram_budget_frac = budget;
        let r = profile_and_place_trace(&cfg, &trace);
        fig.row(
            &format!("budget={budget}"),
            vec![r.hinted_slowdown_pct(), r.improvement_over_cxl_pct()],
        );
    }
    bench.section(fig.render());

    // --- 2. hot-threshold sweep ---
    let mut fig = FigureReport::new(
        "Ablation 2",
        "hint classifier threshold vs outcome",
        &["hinted_slowdown_pct", "hot_bytes_mib"],
    );
    for thr in [0.005, 0.02, 0.1, 0.3, 0.8] {
        let mut cfg = Config::default();
        cfg.porter.hot_threshold = thr;
        let r = profile_and_place_trace(&cfg, &trace);
        fig.row(
            &format!("thr={thr}"),
            vec![r.hinted_slowdown_pct(), r.hint.hot_bytes() as f64 / (1 << 20) as f64],
        );
    }
    bench.section(fig.render());

    // --- 3. DAMON sampling interval: fidelity vs overhead ---
    let mut fig = FigureReport::new(
        "Ablation 3",
        "DAMON sampling interval vs hint quality and profiling overhead",
        &["hinted_slowdown_pct", "relative_overhead"],
    );
    let mut base_samples = None;
    for interval in [1_000u64, 5_000, 25_000, 125_000] {
        let mut cfg = Config::default();
        cfg.monitor.sample_interval_ns = interval;
        cfg.monitor.aggregation_interval_ns = interval * 20;
        let r = profile_and_place_trace(&cfg, &trace);
        // overhead proxy: DAMON samples scale inversely with interval;
        // report relative to the finest setting
        let samples = 1e9 / interval as f64;
        let base = *base_samples.get_or_insert(samples);
        fig.row(
            &format!("{}µs", interval / 1000),
            vec![r.hinted_slowdown_pct(), samples / base],
        );
    }
    bench.section(fig.render());

    // --- 4. policy shoot-out ---
    let cfg = Config::default();
    let mut fig = FigureReport::new(
        "Ablation 4",
        "slowdown vs all-DRAM (%) per placement policy",
        &["slowdown_pct", "promotions", "demotions"],
    );
    let base = replay_plain(&cfg, &trace, TierKind::Dram);
    fig.row("all-dram", vec![0.0, 0.0, 0.0]);
    // all-cxl
    let r = replay_plain(&cfg, &trace, TierKind::Cxl);
    fig.row("all-cxl", vec![r.slowdown_pct_vs(&base), 0.0, 0.0]);
    // first-touch with a DRAM cap (tight server: 25% of footprint)
    let footprint = w.footprint_hint();
    let mut tight = cfg.machine.clone();
    tight.dram_bytes = footprint / 4;
    let r = {
        let mut m = Machine::new(&tight, Box::new(FirstTouchDram::default()));
        m.replay(&trace);
        m.report()
    };
    fig.row("first-touch (25% dram)", vec![r.slowdown_pct_vs(&base), 0.0, 0.0]);
    // TPP-like reactive migration under the same cap
    let r = {
        let mut m = Machine::new(&tight, Box::new(FirstTouchDram::default()));
        m.set_migrator(Box::new(TppMigrator::default()));
        m.set_tick_interval_ns(cfg.monitor.aggregation_interval_ns as f64);
        m.replay(&trace);
        m.report()
    };
    fig.row(
        "tpp-like (25% dram)",
        vec![r.slowdown_pct_vs(&base), r.promotions as f64, r.demotions as f64],
    );
    // static hints under the same cap
    let mut cfg_tight = cfg.clone();
    cfg_tight.machine.dram_bytes = footprint / 4;
    cfg_tight.porter.dram_budget_frac = 0.25;
    let rr = profile_and_place_trace(&cfg_tight, &trace);
    let hinted_slowdown = rr.hinted.wall_ns / base.wall_ns * 100.0 - 100.0;
    fig.row("static-hint (25% dram)", vec![hinted_slowdown, 0.0, 0.0]);
    bench.section(fig.render());

    bench.run();
}
