//! End-to-end lifecycle bench: keep-alive policy × warm-pool budget ×
//! arrival pattern, over a 2-node fleet with snapshots demoted into the
//! shared CXL pool.
//!
//! The sweep quantifies what the warm path buys: sandbox cold starts,
//! per-kind (cold/warm/restored) p50 latency, snapshot/restore traffic,
//! and the pool capacity the snapshot store leases. The zero-budget
//! column is the "warm pool disabled" baseline — every invocation pays
//! the full cold start (restores only when snapshots are on), so the
//! cold-start amortization trend is directly visible across budgets.
//! Writes the series to `BENCH_lifecycle.json` at the repo root so
//! future PRs have a trajectory to compare against.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench e2e_lifecycle

use porter::bench::{fmt_ns, BenchConfig, BenchSuite, FigureReport};
use porter::cluster::simulate;
use porter::config::Config;
use porter::util::json::Json;

fn base_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.cluster.nodes = 2;
    cfg.cluster.functions = 6;
    cfg.cluster.zipf_theta = 0.9;
    cfg.cluster.rate_per_s = 500.0;
    cfg.cluster.seed = 0x11FE;
    cfg.cluster.autoscale = false;
    cfg.cluster.min_nodes = 1;
    cfg.cluster.max_nodes = 4;
    cfg
}

fn lifecycle_cfg(policy: &str, budget_mb: u64, shape: &str, duration_s: f64) -> Config {
    let mut cfg = base_cfg();
    cfg.cluster.arrivals = shape.to_string();
    cfg.cluster.duration_s = duration_s;
    cfg.lifecycle.enabled = true;
    cfg.lifecycle.policy = policy.to_string();
    cfg.lifecycle.warm_pool_bytes = budget_mb << 20;
    cfg.lifecycle.snapshot = true;
    cfg
}

fn main() {
    let quick = porter::bench::quick_mode();
    let policies = ["ttl", "lru", "histogram"];
    let budgets_mb: &[u64] = if quick { &[0, 512] } else { &[0, 64, 512] };
    let shapes: &[&str] = if quick { &["poisson"] } else { &["poisson", "bursty"] };
    let duration_s = if quick { 0.2 } else { 0.5 };

    let mut suite = BenchSuite::new(
        "e2e: function lifecycle (lifecycle/) — keep-alive policy × pool budget × arrivals",
    )
    .with_config(BenchConfig {
        warmup_iters: 1,
        sample_iters: 3,
        max_time: std::time::Duration::from_secs(60),
    });

    // ---- legacy reference: lifecycle modeling off ----
    let mut legacy = base_cfg();
    legacy.cluster.arrivals = "poisson".to_string();
    legacy.cluster.duration_s = duration_s;
    let legacy_report = simulate(&legacy).expect("legacy run");
    suite.section(format!(
        "legacy (implicit infinite keep-alive): p50 {} with {} hint-cold dispatches of {}",
        fmt_ns(legacy_report.fleet_p50_ns as f64),
        legacy_report.cold_starts,
        legacy_report.completed
    ));

    // ---- the sweep ----
    let mut fig = FigureReport::new(
        "lifecycle-amortization",
        "sandbox cold starts and p50 vs keep-alive policy / budget / arrivals",
        &["cold_starts", "warm_starts", "restores", "p50_ms", "snapshot_mb"],
    );
    let mut series = Vec::new();
    for shape in shapes {
        for policy in policies {
            for &mb in budgets_mb {
                let cfg = lifecycle_cfg(policy, mb, shape, duration_s);
                let r = simulate(&cfg).expect("lifecycle run");
                assert_eq!(
                    r.cold_starts + r.warm_starts + r.restores,
                    r.completed,
                    "start-kind accounting must be exhaustive"
                );
                fig.row(
                    &format!("{shape}/{policy}/{mb}MB"),
                    vec![
                        r.cold_starts as f64,
                        r.warm_starts as f64,
                        r.restores as f64,
                        r.fleet_p50_ns as f64 / 1e6,
                        r.snapshot_bytes as f64 / (1u64 << 20) as f64,
                    ],
                );
                series.push(Json::obj(vec![
                    ("shape", Json::str(*shape)),
                    ("policy", Json::str(policy)),
                    ("warm_pool_mb", Json::num(mb as f64)),
                    ("completed", Json::num(r.completed as f64)),
                    ("cold_starts", Json::num(r.cold_starts as f64)),
                    ("warm_starts", Json::num(r.warm_starts as f64)),
                    ("restores", Json::num(r.restores as f64)),
                    ("p50_ns", Json::num(r.fleet_p50_ns as f64)),
                    ("p99_ns", Json::num(r.fleet_p99_ns as f64)),
                    ("cold_p50_ns", Json::num(r.cold_p50_ns as f64)),
                    ("warm_p50_ns", Json::num(r.warm_p50_ns as f64)),
                    ("restore_p50_ns", Json::num(r.restore_p50_ns as f64)),
                    ("snapshot_bytes", Json::num(r.snapshot_bytes as f64)),
                    ("restore_bytes", Json::num(r.restore_bytes as f64)),
                    (
                        "snapshot_leased_bytes",
                        Json::num(r.snapshot_leased_bytes as f64),
                    ),
                    ("pool_mean_occupancy", Json::num(r.pool_mean_occupancy)),
                    ("pool_peak_occupancy", Json::num(r.pool_peak_occupancy)),
                    ("warm_pool_peak_bytes", Json::num(r.warm_pool_peak_bytes as f64)),
                    ("determinism_token", Json::str(format!("{:#018x}", r.determinism_token))),
                ]));
                eprintln!(
                    "  {shape}/{policy}/{mb}MB: cold {} warm {} restored {} p50 {}",
                    r.cold_starts,
                    r.warm_starts,
                    r.restores,
                    fmt_ns(r.fleet_p50_ns as f64)
                );
            }
        }
    }
    suite.section(fig.render());

    // ---- the acceptance trend: a funded warm pool must beat zero ----
    for shape in shapes {
        let zero = simulate(&lifecycle_cfg("ttl", 0, shape, duration_s)).expect("zero run");
        let funded =
            simulate(&lifecycle_cfg("ttl", 512, shape, duration_s)).expect("funded run");
        assert!(
            funded.cold_starts < zero.cold_starts,
            "{shape}: 512MB pool must cut cold starts ({} vs {})",
            funded.cold_starts,
            zero.cold_starts
        );
        assert!(
            funded.fleet_p50_ns < zero.fleet_p50_ns,
            "{shape}: 512MB pool must cut p50 ({} vs {})",
            funded.fleet_p50_ns,
            zero.fleet_p50_ns
        );
        suite.section(format!(
            "{shape}: cold starts {} → {} and p50 {} → {} (0MB → 512MB warm pool)",
            zero.cold_starts,
            funded.cold_starts,
            fmt_ns(zero.fleet_p50_ns as f64),
            fmt_ns(funded.fleet_p50_ns as f64)
        ));
    }

    // ---- determinism under the lifecycle layer ----
    let check = lifecycle_cfg("histogram", 64, "poisson", duration_s.min(0.2));
    let a = simulate(&check).expect("determinism A");
    let b = simulate(&check).expect("determinism B");
    assert_eq!(
        a.determinism_token, b.determinism_token,
        "lifecycle runs must stay deterministic under a fixed seed"
    );

    // ---- host-side timing of one mid-size configuration ----
    let host_cfg = lifecycle_cfg("ttl", 512, "poisson", 0.2);
    let arrivals = host_cfg.cluster.rate_per_s * 0.2;
    suite.bench_with_throughput("simulate_2n_warmpool", arrivals, "arrival", || {
        simulate(&host_cfg).unwrap()
    });

    // ---- persist the series for future PRs ----
    let out = Json::obj(vec![
        ("suite", Json::str("e2e_lifecycle")),
        ("quick", Json::Bool(quick)),
        ("duration_s", Json::num(duration_s)),
        ("legacy_p50_ns", Json::num(legacy_report.fleet_p50_ns as f64)),
        ("policies", Json::arr(policies.iter().map(|p| Json::str(*p)))),
        ("budgets_mb", Json::arr(budgets_mb.iter().map(|b| Json::num(*b as f64)))),
        ("series", Json::Arr(series)),
    ]);
    let path = std::env::var("PORTER_BENCH_JSON")
        .unwrap_or_else(|_| concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_lifecycle.json").into());
    match std::fs::write(&path, out.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    suite.run();
}
