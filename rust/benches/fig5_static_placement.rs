//! Fig. 5 — "Performance improvement of static placement over pure CXL
//! for PageRank and BFS on Twitter dataset."
//!
//! Runs the full §3 pipeline (record the Trace-IR once → replay with
//! DAMON on pure CXL → hint → replay with hot objects pinned to DRAM)
//! for BFS and PageRank on the Twitter-like RMAT graph, plus the §1
//! headline check: hinted placement pulls the pure-CXL slowdown down
//! toward the all-DRAM line. Each workload algorithm executes exactly
//! once; every pass is an IR replay.
//!
//! Paper shape: PageRank up to ~26% execution-time reduction vs pure
//! CXL; headline: ~30% slowdown (pure CXL) cut to a small residual.
//!
//! Quick run: PORTER_BENCH_QUICK=1 cargo bench --bench fig5_static_placement

use porter::bench::{BenchSuite, FigureReport};
use porter::config::Config;
use porter::placement::static_place::profile_and_place;
use porter::workloads::registry::{build, Scale};

fn main() {
    let quick = porter::bench::quick_mode();
    let scale = if quick { Scale::Small } else { Scale::Default };
    let cfg = Config::default();
    let mut bench =
        BenchSuite::new("fig5: static placement vs pure CXL (BFS + PageRank, Twitter-like RMAT)");

    let mut fig = FigureReport::new(
        "Figure 5",
        "improvement over pure CXL (%), with slowdowns vs all-DRAM for context",
        &["improvement_over_cxl_pct", "cxl_slowdown_pct", "hinted_slowdown_pct"],
    );
    for name in ["pagerank", "bfs"] {
        let w = build(name, scale).expect("workload");
        let t0 = std::time::Instant::now();
        let r = profile_and_place(&cfg, w.as_ref());
        assert_eq!(r.checksums[0], r.checksums[2], "{name}: placement changed results");
        eprintln!(
            "  {name:9} cxl +{:.1}% → hinted +{:.1}% (improvement {:.1}%, host {:.0}s)",
            r.cxl_slowdown_pct(),
            r.hinted_slowdown_pct(),
            r.improvement_over_cxl_pct(),
            t0.elapsed().as_secs_f64()
        );
        fig.row(
            name,
            vec![r.improvement_over_cxl_pct(), r.cxl_slowdown_pct(), r.hinted_slowdown_pct()],
        );
        bench.section(format!(
            "{name}: hot objects = {:?}\n",
            r.hint
                .objects
                .iter()
                .filter(|o| o.class == porter::placement::HeatClass::Hot)
                .map(|o| o.site.clone())
                .collect::<Vec<_>>()
        ));
    }
    bench.section(fig.render());
    bench.section(
        "paper: PageRank up to ~26% reduction over pure CXL; §1 headline: naive hot-object\n\
         placement brings slowdown from ~30% (pure CXL) to a small residual."
            .to_string(),
    );
    bench.run();
}
